"""Deterministic synthetic data pipeline (host-sharded, prefetching)."""
from .pipeline import (
    DataConfig,
    PrefetchIterator,
    SyntheticCorpus,
    device_put_batch,
)

__all__ = ["DataConfig", "PrefetchIterator", "SyntheticCorpus",
           "device_put_batch"]
