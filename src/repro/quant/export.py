"""Integer deployment export: QAT params -> INT8 codes + PO2 shift exponents.

``export_quantized`` walks a calibrated params tree and replaces every
quantized linear's float weight + ``QuantState`` with a
``DeployedQuantState``:

  * weight codes via ``po2_quantize_codes`` (INT8 at the per-channel
    power-of-two scale ``2^floor(log2 aw)`` — bit-exact by construction);
  * activation scale snapped to ``2^floor(log2 ax)``;
  * PSUM shift exponents ``e_i = floor(ap_i) - ax_exp - aw_exp`` in
    product-scale units, the exact layout ``kernels/apsq_matmul`` (and its
    jnp oracle ``ref.apsq_matmul_ref``) consumes.

The deployed tree runs through the ordinary model ``forward`` /
``decode_step`` / ``serving.ServingEngine`` — ``models.common.dense``
dispatches on ``DeployedQuantState`` into the true-integer path
(``repro.core.deployed_dense``).  ``snap_params_po2`` returns the matching
fake-quant reference (same tree, ax/aw snapped to the exported PO2 grid):
deployed and snapped-fake outputs agree to within the rounding-mode gap of
the hardware shifter (round-half-up vs round-half-even — at most one LSB
of the largest PSUM scale per quantization step, see
``tests/test_system.py::test_kernel_agrees_with_fakequant_reference``).

Scan-stacked linears (leading ``n_units`` axis) are exported per unit via
``vmap`` and stay scan-compatible.  MoE expert tensors export the same
way over the expert axis: ``{"wi": [E, K, N], "qp_wi": QuantState}``
becomes a stacked ``DeployedQuantState`` whose data leaves carry a
leading expert axis — per-expert INT8 codes and per-expert exponent
banks — executed by ``repro.exec.execute_expert_gemm``.  The
tied-embedding head (``{"table", "qp_head"}`` after ``calibrate_model``)
exports its transposed table as INT8 codes + shift exponents while the
float table stays for the input lookup; ``models.model.logits_from_hidden``
routes the logits GEMM through the exec backend.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (
    DeployedQuantState,
    QuantState,
    effective_n_p,
    po2_quantize_codes,
    tied_head_weight,
)


def _export_one(w: jax.Array, qp: QuantState):
    """Export a single [K, *out] weight + state.

    Returns ``(DeployedQuantState, n_clamped)`` where n_clamped counts
    PSUM shifts that would have been negative (a PSUM scale finer than
    the product scale; the hardware shifter cannot left-shift-quantize,
    so they are clamped to 0)."""
    spec = qp.spec
    k = w.shape[0]
    w2d = w.reshape(k, -1).astype(jnp.float32)
    log2_aw = jnp.log2(jnp.maximum(qp.aw.astype(jnp.float32), 1e-30))
    w_codes, aw_exp = po2_quantize_codes(w2d, log2_aw, bits=spec.w_bits)
    ax_exp = jnp.floor(
        jnp.log2(jnp.maximum(qp.ax.astype(jnp.float32), 1e-30))
    ).astype(jnp.int32)
    psum_exps = None
    n_clamped = jnp.zeros((), jnp.int32)
    if qp.ap is not None:
        ap_exp = jnp.floor(qp.ap.astype(jnp.float32)).astype(jnp.int32)
        if aw_exp.ndim:  # per-channel weights -> per-(tile, column) shifts
            psum_exps = ap_exp[:, None] - ax_exp - aw_exp[None, :]
        else:
            psum_exps = ap_exp - ax_exp - aw_exp
        n_clamped = jnp.sum(psum_exps < 0).astype(jnp.int32)
        psum_exps = jnp.maximum(psum_exps, 0)
    return DeployedQuantState(
        w_codes=w_codes, ax_exp=ax_exp, aw_exp=aw_exp, psum_exps=psum_exps,
        spec=spec, name=qp.name, out_dims=tuple(w.shape[1:])), n_clamped


def _snap_one(qp: QuantState) -> QuantState:
    """Snap ax/aw to the exported PO2 grid (fake-quant reference view)."""
    aw = jnp.exp2(jnp.floor(
        jnp.log2(jnp.maximum(qp.aw.astype(jnp.float32), 1e-30))))
    ax = jnp.exp2(jnp.floor(
        jnp.log2(jnp.maximum(qp.ax.astype(jnp.float32), 1e-30))))
    return dataclasses.replace(qp, aw=aw, ax=ax)


def _is_stacked(qp: QuantState) -> bool:
    # per-linear ax is a scalar; a leading scan axis makes it 1-D
    return qp.ax.ndim == 1


def export_quantized(params, policy=None):
    """Export every quantized linear to the integer deployment format.

    Walks the params tree for ``{"w": ..., "qp": QuantState}`` subtrees
    and replaces them with ``{"qp": DeployedQuantState}`` (the float
    weight is dropped — the codes + exponents are the deployment
    artifact).  MoE expert containers (``{"wi": [E, K, N], "qp_wi":
    QuantState, ...}``) export per expert: the float bank is dropped and
    ``qp_wi`` becomes a stacked ``DeployedQuantState`` with per-expert
    codes + exponent banks.  A tied-embedding head calibrated by
    ``calibrate_model`` (``{"table", "qp_head"}``) exports its transposed
    table; the float table stays for the input lookup.  ``policy``
    optionally overrides each layer's spec (e.g. re-deploying with a
    different per-layer gs without re-training PSUM scales is legal as
    long as n_p is unchanged).

    Returns ``(deploy_params, report)`` — report maps layer name to
    {k, n, n_p, gs, mode, int8_bytes, clamped_exps}.
    """
    report: dict = {}

    def apply_policy(qp: QuantState, k: int) -> QuantState:
        if policy is None:
            return qp
        override = policy.resolve(qp.name)
        if override is None or not override.enabled:
            return qp
        if override.psum.mode != "none":
            if qp.ap is None:
                raise ValueError(
                    f"{qp.name}: export policy requests psum mode "
                    f"{override.psum.mode!r} but the layer was "
                    f"calibrated without PSUM scales — re-run "
                    f"calibration with that policy first")
            n_p = qp.ap.shape[-1]
            eff = effective_n_p(k, override.psum.n_p)
            if eff != n_p:
                raise ValueError(
                    f"{qp.name}: export policy n_p="
                    f"{override.psum.n_p} (effective {eff} for "
                    f"K={k}) != calibrated n_p={n_p}")
            override = dataclasses.replace(
                override, psum=dataclasses.replace(override.psum, n_p=eff))
        return dataclasses.replace(qp, spec=override)

    def record(dq, spec, n_clamped, name, **extra):
        prev = report.get(name)
        report[name] = {
            "k": int(dq.w_codes.shape[-2]), "n": int(dq.w_codes.shape[-1]),
            "mode": spec.psum.mode if spec else "none",
            "gs": spec.psum.gs if spec else None,
            "n_p": spec.psum.n_p if spec else None,
            "int8_bytes": int(dq.w_codes.size),
            "clamped_exps": int(jnp.sum(n_clamped)),
            # unstacked units share pattern-position names; count them
            "count": 1 + (prev["count"] if prev else 0),
            **extra,
        }

    def export_linear(w, qp: QuantState):
        stacked = _is_stacked(qp)
        qp = apply_policy(qp, int(w.shape[1] if stacked else w.shape[0]))
        if stacked:
            # vmap over the scan-stacked leading axis; out_dims metadata is
            # set inside _export_one from the per-unit weight shape
            dq, n_clamped = jax.vmap(_export_one, in_axes=(0, 0))(w, qp)
            n_units = int(w.shape[0])
        else:
            dq, n_clamped = _export_one(w, qp)
            n_units = 1
        record(dq, qp.spec, n_clamped, qp.name, n_units=n_units)
        return {"qp": dq}

    def export_experts(w, qp: QuantState):
        """MoE expert bank [E, K, N] (or scan-stacked [U, E, K, N]) +
        shared state -> stacked deployed state with per-expert codes and
        exponent banks (the shared calibrated scales replicate over E,
        matching the fake-quant semantics of ``models.moe._expert_gemm``
        expert-for-expert)."""
        qp = apply_policy(qp, int(w.shape[-2]))
        per_expert = jax.vmap(_export_one, in_axes=(0, None))
        if _is_stacked(qp):  # [U, E, K, N] with per-unit quantizer state
            dq, n_clamped = jax.vmap(per_expert, in_axes=(0, 0))(
                w.astype(jnp.float32), qp)
        else:
            dq, n_clamped = per_expert(w.astype(jnp.float32), qp)
        record(dq, qp.spec, n_clamped, qp.name, n_experts=int(w.shape[-3]))
        return dq

    def export_head(table, qp: QuantState):
        """Tied-embedding head: codes for table.T ([D, V]); the float
        table itself stays in the tree for the input embedding lookup."""
        w = tied_head_weight(table)
        qp = apply_policy(qp, int(w.shape[0]))
        dq, n_clamped = _export_one(w, qp)
        record(dq, qp.spec, n_clamped, qp.name, tied_head=True)
        return dq

    def walk(tree):
        if not isinstance(tree, dict):
            return tree
        if "w" in tree and isinstance(tree.get("qp"), QuantState):
            return export_linear(tree["w"], tree["qp"])
        if "table" in tree and isinstance(tree.get("qp_head"), QuantState):
            out = {k: walk(v) for k, v in tree.items() if k != "qp_head"}
            out["qp_head"] = export_head(tree["table"], tree["qp_head"])
            return out
        # Expert banks: [E, K, N] floats next to a shared QuantState, or
        # scan-stacked [U, E, K, N] next to a unit-stacked QuantState.
        experts = [k[3:] for k in tree
                   if k.startswith("qp_") and k[3:] in tree
                   and isinstance(tree[k], QuantState)
                   and getattr(tree[k[3:]], "ndim", 0)
                   == (4 if _is_stacked(tree[k]) else 3)]
        if experts:
            out = {}
            for k, v in tree.items():
                if k in experts:
                    continue  # float expert bank dropped from deployment
                if k.startswith("qp_") and k[3:] in experts:
                    out[k] = export_experts(tree[k[3:]], v)
                else:
                    out[k] = walk(v)
            return out
        return {k: walk(v) for k, v in tree.items()}

    return walk(params), report


def snap_params_po2(params):
    """Fake-quant reference matching the export: same tree, with every
    ``QuantState``'s ax/aw snapped to ``2^floor(log2 .)``.  Running the
    model on this tree reproduces the deployed integer path up to the
    shifter's rounding mode."""
    def walk(tree):
        if isinstance(tree, QuantState):
            return _snap_one(tree)
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        return tree
    return walk(params)
