"""Block autotuner: shape classing, heuristic properties, cache behavior.

``get_block_config`` is the lookup every kernel launch goes through, so
its two invariants matter most: it never times anything (CI interpret
mode must stay deterministic), and the same cache key always resolves to
the same config — cold (fresh process view of the on-disk table) or warm
(in-memory).  ``tune`` is exercised with an injected deterministic
``measure`` so tests never depend on wall-clock.
"""
import json
import os

import pytest

from repro.kernels import autotune
from repro.kernels.autotune import (
    BlockConfig,
    VMEM_BUDGET_BYTES,
    candidate_configs,
    cache_key,
    get_block_config,
    heuristic_config,
    shape_class,
    tune,
)


@pytest.fixture()
def cache_file(tmp_path):
    """Fresh on-disk cache per test; memory view cleared before and after."""
    autotune.clear_memory_cache()
    yield str(tmp_path / "autotune.json")
    autotune.clear_memory_cache()


# ---------------------------------------------------------------------------
# Shape classes + heuristic
# ---------------------------------------------------------------------------

def test_shape_classes():
    assert shape_class(1) == "decode_m1"
    assert shape_class(2) == "small_m"
    assert shape_class(32) == "small_m"
    assert shape_class(33) == "prefill"
    assert shape_class(1024) == "prefill"
    assert shape_class(1, expert=True) == "expert"
    assert shape_class(256, expert=True) == "expert"


def test_heuristic_decode_is_single_row():
    cfg = heuristic_config("decode_m1", 1, 1024, 512, n_p=8, gs=2)
    assert cfg.block_m == 1
    assert cfg.source == "heuristic"


def test_heuristic_prefill_tiles_exceed_old_caps():
    """The old resolver capped every launch at 8x128; the shape-class
    heuristic must hand prefill shapes materially larger tiles."""
    cfg = heuristic_config("prefill", 256, 1024, 512, n_p=8, gs=2)
    assert cfg.block_m > 8 and cfg.block_n > 128


def test_heuristic_small_shapes_get_single_tile():
    """Blocks never exceed the padded dims (one launch covers the GEMM)."""
    cfg = heuristic_config("prefill", 40, 64, 130, n_p=4, gs=2)
    assert cfg.block_m == 40  # _round_up(40, 8)
    assert cfg.block_n == 256  # _round_up(130, 128)


@pytest.mark.parametrize("cls,m", [("decode_m1", 1), ("small_m", 16),
                                   ("prefill", 256), ("expert", 64)])
def test_heuristic_respects_vmem_budget(cls, m):
    k, n, n_p, gs = 8192, 8192, 8, 4
    cfg = heuristic_config(cls, m, k, n, n_p=n_p, gs=gs)
    bk = -(-k // n_p)
    used = autotune._vmem_bytes(cfg.block_m, cfg.block_n, bk, gs, n_p,
                                cfg.exp_layout, n)
    assert used <= VMEM_BUDGET_BYTES


def test_candidates_deterministic_and_feasible():
    a = candidate_configs("prefill", 256, 1024, 512, n_p=8, gs=2)
    b = candidate_configs("prefill", 256, 1024, 512, n_p=8, gs=2)
    assert a == b and len(a) > 1
    assert all(c.source == "tuned" for c in a)
    # decode_m1 pins the fast-path row; expert pins the blocked layout
    assert {c.block_m for c in
            candidate_configs("decode_m1", 1, 1024, 512, n_p=8, gs=2)} \
        == {1}
    assert {c.exp_layout for c in
            candidate_configs("expert", 64, 512, 256, n_p=8, gs=2)} \
        == {"blocked"}


# ---------------------------------------------------------------------------
# Cache determinism
# ---------------------------------------------------------------------------

def _fake_measure(cfg, m, k, n, **kw):
    """Deterministic cost model: prefer bn=256 then bm=64, no clock."""
    return abs(cfg.block_n - 256) + abs(cfg.block_m - 64) / 10.0


def test_get_block_config_never_times(cache_file, monkeypatch):
    """The launch-path lookup must not touch the measurement path."""
    def boom(*a, **k):
        raise AssertionError("get_block_config invoked the timer")
    monkeypatch.setattr(autotune, "_default_measure", boom)
    cfg = get_block_config(256, 1024, 512, n_p=8, gs=2, path=cache_file)
    assert cfg.source == "heuristic"


def test_tune_same_key_same_config_cold_vs_warm(cache_file):
    """tune -> warm lookup == cold (re-read from disk) lookup, and a
    second tune with the same measurements lands the same winner."""
    win1 = tune(256, 1024, 512, n_p=8, gs=2, path=cache_file,
                measure=_fake_measure)
    warm = get_block_config(256, 1024, 512, n_p=8, gs=2, path=cache_file)
    autotune.clear_memory_cache()  # force re-read of the on-disk table
    cold = get_block_config(256, 1024, 512, n_p=8, gs=2, path=cache_file)
    assert warm == cold
    assert warm.source == "tuned"
    assert (warm.block_m, warm.block_n) == (win1.block_m, win1.block_n)
    win2 = tune(256, 1024, 512, n_p=8, gs=2, path=cache_file,
                measure=_fake_measure)
    assert win1 == win2


def test_tuned_entry_applies_per_key_only(cache_file):
    """A winner tuned for (prefill, np=8, gs=2) must not leak onto other
    shape classes or other (n_p, gs) keys."""
    tune(256, 1024, 512, n_p=8, gs=2, path=cache_file,
         measure=_fake_measure)
    same_cls = get_block_config(512, 2048, 512, n_p=8, gs=2,
                                path=cache_file)
    assert same_cls.source == "tuned"
    other_np = get_block_config(256, 1024, 512, n_p=4, gs=2,
                                path=cache_file)
    assert other_np.source == "heuristic"
    decode = get_block_config(1, 1024, 512, n_p=8, gs=2, path=cache_file)
    assert decode.source == "heuristic" and decode.block_m == 1


def test_tuned_winner_clamps_to_smaller_shape(cache_file):
    """A winner tuned at a large representative shape stays legal on a
    smaller same-class shape (blocks never exceed the padded dims)."""
    tune(256, 1024, 512, n_p=8, gs=2, path=cache_file,
         measure=_fake_measure)
    small = get_block_config(40, 64, 130, n_p=8, gs=2, path=cache_file)
    assert small.source == "tuned"
    assert small.block_m <= 40 and small.block_n <= 256


def test_cache_file_versioned_and_keyed(cache_file):
    tune(1, 1024, 512, n_p=8, gs=2, path=cache_file,
         measure=_fake_measure)
    with open(cache_file) as f:
        payload = json.load(f)
    assert payload["version"] == autotune.CACHE_VERSION
    key = cache_key("decode_m1", 8, 2)
    assert key in payload["entries"]
    assert payload["entries"][key]["block_m"] == 1


def test_corrupt_cache_falls_back_to_heuristic(cache_file):
    with open(cache_file, "w") as f:
        f.write("{not json")
    cfg = get_block_config(256, 1024, 512, n_p=8, gs=2, path=cache_file)
    assert cfg.source == "heuristic"


def test_env_var_picks_cache_path(tmp_path, monkeypatch):
    p = str(tmp_path / "env-cache.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", p)
    assert autotune.cache_path() == p
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE")
    assert autotune.cache_path().endswith(
        os.path.join("repro-apsq",
                     f"autotune-v{autotune.CACHE_VERSION}.json"))


def test_resolved_table_covers_all_classes(cache_file, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", cache_file)
    autotune.clear_memory_cache()
    table = autotune.resolved_table()
    assert set(table) == set(autotune.SHAPE_CLASSES)
    for rec in table.values():
        assert {"block_m", "block_n", "exp_layout",
                "blocks_source"} <= set(rec)
