"""Execution backends for deployed integer GEMMs.

The deployed model (``DeployedQuantState`` params, see ``repro.quant.export``)
describes *what* to compute — INT8 codes, PO2 shift exponents, Algorithm-1
PSUM handling — but not *how*.  This module owns the "how": a small registry
of backends behind one entry point, ``execute_gemm``:

  * ``oracle`` — the pure-jnp integer semantics
    (``kernels/apsq_matmul/ref``).  Runs anywhere, shape-polymorphic,
    differentiable-adjacent; the reference all other backends must match
    bit-for-bit.
  * ``pallas`` — the real ``kernels/apsq_matmul`` Pallas TPU kernel
    (INT8 PSUM banks in VMEM).  On CPU it runs in interpret mode, so the
    same code path is CI-testable; on TPU it is the hardware datapath the
    paper's energy claims (§V) ride on.
  * ``auto``   — ``pallas`` when the default JAX backend is TPU, else
    ``oracle``.  The serving default: decode hits the kernel on hardware
    and stays bit-identical on CPU.

Every projection GEMM in the model zoo dispatches here when its params are
deployed (``models.common.dense`` -> ``core.deployed_dense`` ->
``execute_gemm``), including MoE expert banks and the tied-embedding head,
so QAT fake-quant, the oracle, and the kernel are provably one semantics
on a single code path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import DeployedQuantState, QuantConfig, qrange


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class ExecBackend:
    """How the integer op families on exported/quantized data are computed.

    Two op families, one registry:

    * ``int_gemm`` consumes INT8 activation codes [M, K], a deployed
      layer's weight codes [K, N] and PSUM shift exponents ([n_p] or
      [n_p, N]; None for plain W8A8) and returns the INT32 result in
      product-scale units.
    * ``kv_attention`` consumes a query (float), an INT8 KV cache
      ([B, S, Hkv, hd] codes with per-(batch, head) PO2 exponents) and
      per-batch valid lengths, and returns attention output — the serving
      engine's paged-cache read path.  A 3D query [B, Hq, hd] is one
      decode row; a 4D query [B, C, Hq, hd] is a causal prefill chunk
      whose last row sits at cache position ``length - 1``.
    """

    name = "base"

    def int_gemm(self, x_codes: jax.Array, w_codes: jax.Array,
                 psum_exps: jax.Array | None, *, gs: int) -> jax.Array:
        raise NotImplementedError

    def int_expert_gemm(self, x_codes: jax.Array, w_codes: jax.Array,
                        psum_exps: jax.Array | None, *,
                        gs: int) -> jax.Array:
        """Stacked expert-bank GEMM: [E, M, K] @ [E, K, N] -> [E, M, N].

        Default semantics: E independent ``int_gemm`` calls (the
        reference unrolled form).  Backends that can fuse the expert
        axis into one launch override this — the Pallas backend serves
        all experts from a single ``pallas_call`` grid.
        """
        n_exp = int(x_codes.shape[0])
        return jnp.stack([
            self.int_gemm(
                x_codes[e], w_codes[e],
                None if psum_exps is None else psum_exps[e], gs=gs)
            for e in range(n_exp)])

    def kv_attention(self, q: jax.Array, k_codes: jax.Array,
                     v_codes: jax.Array, k_exp: jax.Array,
                     v_exp: jax.Array, length: jax.Array, *,
                     block_s: int) -> jax.Array:
        raise NotImplementedError

    def resolve(self) -> "ExecBackend":
        """The concrete backend that will execute (identity for leaves)."""
        return self

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class OracleBackend(ExecBackend):
    """Pure-jnp semantics (``apsq_matmul.ref`` / ``int8_kv_attention.ref``)."""

    name = "oracle"

    def int_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        from repro.kernels.apsq_matmul import ref  # lazy: keep import light
        if psum_exps is None:
            return ref.baseline_matmul_ref(x_codes, w_codes)
        n_p = int(psum_exps.shape[0])
        return ref.apsq_matmul_ref(x_codes, w_codes, psum_exps,
                                   n_p=n_p, gs=gs)

    def kv_attention(self, q, k_codes, v_codes, k_exp, v_exp, length, *,
                     block_s):
        from repro.kernels.int8_kv_attention import int8_kv_attention_ref
        return int8_kv_attention_ref(q, k_codes, v_codes, k_exp, v_exp,
                                     length)


class PallasBackend(ExecBackend):
    """The real Pallas kernels (interpret mode off-TPU, hardware on TPU).

    ``interpret=None`` auto-selects (interpret unless running on TPU);
    pass ``interpret=True`` to force the interpreter (CI determinism).

    Launch geometry comes from ``repro.kernels.autotune``: every GEMM
    resolves (block_m, block_n, exponent layout) per shape class —
    cached tuned winners when ``python -m repro.kernels.autotune`` (or
    ``kernel_bench --tune``) has run on this host, the static heuristic
    otherwise.  ``block_overrides`` pins configs per shape class
    (e.g. ``{"decode_m1": BlockConfig(1, 512)}``) ahead of both.
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None,
                 block_overrides: dict | None = None):
        self.interpret = interpret
        self.block_overrides = dict(block_overrides or {})

    def _blocks(self, m: int, *, expert: bool = False):
        """(block_m, block_n, exp_layout) or (None, None, None) to let
        ops.py resolve through the autotune table."""
        from repro.kernels import autotune
        cfg = self.block_overrides.get(
            autotune.shape_class(m, expert=expert))
        if cfg is None:
            return None, None, None
        return cfg.block_m, cfg.block_n, cfg.exp_layout

    def int_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        from repro.kernels.apsq_matmul import (
            apsq_matmul_int8,
            baseline_matmul_int8,
        )
        bm, bn, layout = self._blocks(int(x_codes.shape[0]))
        if psum_exps is None:
            return baseline_matmul_int8(x_codes, w_codes, n_p=1,
                                        block_m=bm, block_n=bn,
                                        interpret=self.interpret)
        return apsq_matmul_int8(x_codes, w_codes, psum_exps, gs=gs,
                                block_m=bm, block_n=bn, exp_layout=layout,
                                interpret=self.interpret)

    def int_expert_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        from repro.kernels.apsq_matmul import (
            apsq_expert_matmul_int8,
            baseline_expert_matmul_int8,
        )
        bm, bn, _ = self._blocks(int(x_codes.shape[1]), expert=True)
        if psum_exps is None:
            return baseline_expert_matmul_int8(
                x_codes, w_codes, n_p=1, block_m=bm, block_n=bn,
                interpret=self.interpret)
        return apsq_expert_matmul_int8(
            x_codes, w_codes, psum_exps, gs=gs, block_m=bm, block_n=bn,
            interpret=self.interpret)

    def kv_attention(self, q, k_codes, v_codes, k_exp, v_exp, length, *,
                     block_s):
        from repro.kernels.int8_kv_attention import int8_kv_attention
        if q.ndim == 4:
            # Chunked prefill: resolve the KV tile through the
            # ``prefill_attn`` shape class (tuned winner or heuristic;
            # ``block_overrides`` pins it), snapped to a divisor of S.
            from repro.kernels import autotune
            cfg = self.block_overrides.get("prefill_attn")
            if cfg is None:
                cfg = autotune.get_block_config(
                    int(q.shape[1]), int(q.shape[-1]),
                    int(k_codes.shape[1]), n_p=1, gs=1, attn=True)
            block_s = kv_block_size(int(k_codes.shape[1]), cfg.block_n)
        return int8_kv_attention(q, k_codes, v_codes, k_exp, v_exp, length,
                                 block_s=block_s, interpret=self.interpret)


class AutoBackend(ExecBackend):
    """``pallas`` on TPU, ``oracle`` elsewhere (resolved at trace time)."""

    name = "auto"

    def resolve(self) -> ExecBackend:
        if jax.default_backend() == "tpu":
            return get_backend("pallas")
        return get_backend("oracle")

    def int_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        return self.resolve().int_gemm(x_codes, w_codes, psum_exps, gs=gs)

    def int_expert_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        return self.resolve().int_expert_gemm(x_codes, w_codes, psum_exps,
                                              gs=gs)

    def kv_attention(self, q, k_codes, v_codes, k_exp, v_exp, length, *,
                     block_s):
        return self.resolve().kv_attention(q, k_codes, v_codes, k_exp,
                                           v_exp, length, block_s=block_s)


class ShardedBackend(ExecBackend):
    """Mesh-parallel integer execution: the local ``inner`` backend per
    shard, INT8-on-the-wire combines between shards.

    Wraps any leaf backend (``oracle``/``pallas``/an instance) and runs it
    inside ``repro.dist.shard_map`` over the mesh's ``model`` axis, with
    the shard axis chosen per layer by ``repro.dist.tp.plan_gemm`` from
    the same static shapes ``tp.shard_deployed`` placed the codes with:
    PSQ layers K-shard by whole PSUM tiles (int32 ``psum_scatter`` + int8
    code gather), APSQ layers column-parallel over N (lossless int8 code
    ``all_gather`` — the output is a code times the static ``2^e_last``),
    W8A8 K-shards with a full-precision int32 psum, MoE expert banks run
    expert-parallel with an int8 code gather as the all-to-all, and KV
    attention splits heads.  Every path is bit-exact to ``inner`` on one
    device; ``wire="fp32"`` swaps the int8 collectives for 4-byte gathers
    (identical results — the parity-debugging fallback ``dist_bench``
    prices the int8 path against).

    The registered ``backend="sharded"`` instance has no mesh and simply
    delegates to ``auto`` — construct ``ShardedBackend(mesh=...)`` (or
    pass ``mesh=`` to ``PagedServingEngine``, which wraps its backend
    automatically) for real multi-device serving.
    """

    name = "sharded"

    def __init__(self, mesh=None, inner="auto", *,
                 model_axis: str = "model", wire: str = "int8"):
        if wire not in ("int8", "fp32"):
            raise ValueError(f"wire must be 'int8' or 'fp32', got {wire!r}")
        self.mesh = mesh
        self.inner = inner
        self.model_axis = model_axis
        self.wire = wire

    def _leaf(self) -> ExecBackend:
        return get_backend(self.inner).resolve()

    def int_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        if self.mesh is None:
            return self._leaf().int_gemm(x_codes, w_codes, psum_exps, gs=gs)
        from repro.dist.tp import sharded_int_gemm  # lazy: dist -> kernels
        return sharded_int_gemm(self.mesh, self._leaf(), x_codes, w_codes,
                                psum_exps, gs=gs, model_axis=self.model_axis,
                                wire=self.wire)

    def int_expert_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        if self.mesh is None:
            return self._leaf().int_expert_gemm(x_codes, w_codes, psum_exps,
                                                gs=gs)
        from repro.dist.tp import sharded_int_expert_gemm
        return sharded_int_expert_gemm(
            self.mesh, self._leaf(), x_codes, w_codes, psum_exps, gs=gs,
            model_axis=self.model_axis, wire=self.wire)

    def kv_attention(self, q, k_codes, v_codes, k_exp, v_exp, length, *,
                     block_s):
        if self.mesh is None:
            return self._leaf().kv_attention(q, k_codes, v_codes, k_exp,
                                             v_exp, length, block_s=block_s)
        from repro.dist.tp import sharded_kv_attention
        return sharded_kv_attention(
            self.mesh, self._leaf(), q, k_codes, v_codes, k_exp, v_exp,
            length, block_s=block_s, model_axis=self.model_axis)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_backend(name: str, backend: ExecBackend) -> None:
    _REGISTRY[name] = backend


register_backend("oracle", OracleBackend())
register_backend("pallas", PallasBackend())
register_backend("auto", AutoBackend())
register_backend("sharded", ShardedBackend())

DEFAULT_BACKEND = "auto"


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_backend(backend=None) -> ExecBackend:
    """Resolve a backend name / instance / None (-> the ``auto`` default)."""
    if backend is None:
        backend = DEFAULT_BACKEND
    if isinstance(backend, ExecBackend):
        return backend
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise KeyError(f"unknown exec backend {backend!r}; "
                       f"known: {available_backends()}") from None


# ---------------------------------------------------------------------------
# The one entry point the model zoo dispatches through
# ---------------------------------------------------------------------------

def quantize_activations(x2d: jax.Array, ax_exp: jax.Array,
                         a_bits: int = 8) -> jax.Array:
    """Float activations [M, K] -> INT8 codes at the PO2 scale 2^ax_exp."""
    qn, qp = qrange(a_bits, True)
    xf = x2d.astype(jnp.float32)
    return jnp.clip(jnp.round(xf * jnp.exp2(-ax_exp.astype(jnp.float32))),
                    qn, qp).astype(jnp.int8)


def execute_gemm(dq: DeployedQuantState, x: jax.Array, *,
                 backend=None) -> jax.Array:
    """Run one deployed linear: quantize -> integer GEMM -> rescale.

    ``x`` is [..., K] float; the result is [..., *dq.out_dims] in x.dtype.
    The leading dims are flattened to M (decode's [B, 1, C] becomes M=B,
    prefill's [B, T, C] becomes M=B*T) — the backend sees one [M, K] x
    [K, N] integer GEMM, pads to its block constraints (including ragged
    ``K % n_p`` via a zero-contribution remainder PSUM group), and the
    INT32 product-scale output is rescaled by ``2^(ax_exp + aw_exp)``.
    """
    backend = get_backend(backend).resolve()
    spec = dq.spec or QuantConfig.w8a8()
    k = dq.w_codes.shape[-2]
    out_shape = x.shape[:-1] + dq.out_dims
    xc = quantize_activations(x.reshape(-1, k), dq.ax_exp, spec.a_bits)
    gs = 1
    if dq.psum_exps is not None:
        n_p = int(dq.psum_exps.shape[0])
        gs = n_p if spec.psum.mode == "psq" else spec.psum.gs
    y = backend.int_gemm(xc, dq.w_codes, dq.psum_exps, gs=gs)
    scale = jnp.exp2((dq.ax_exp + dq.aw_exp).astype(jnp.float32))
    return (y.astype(jnp.float32) * scale).astype(x.dtype).reshape(out_shape)


def kv_block_size(seq_len: int, requested: int = 512) -> int:
    """Largest divisor of ``seq_len`` that is <= ``requested``.

    The Pallas KV kernel tiles the cache sequence into ``block_s`` chunks
    and requires an exact tiling; the oracle ignores it.  Paged caches
    pass their page size, which divides the gathered sequence by
    construction.
    """
    b = max(1, min(requested, seq_len))
    while seq_len % b:
        b -= 1
    return b


def execute_kv_attention(q: jax.Array, k_codes: jax.Array,
                         v_codes: jax.Array, k_exp: jax.Array,
                         v_exp: jax.Array, length: jax.Array, *,
                         block_s: int | None = None,
                         backend=None) -> jax.Array:
    """Attention over an INT8 KV cache through the backend registry.

    q: [B, Hq, hd] float (decode: one row) or [B, C, Hq, hd] (prefill
    chunk: C causal rows ending at cache position ``length - 1``);
    k_codes/v_codes: [B, S, Hkv, hd] int8 with per-(batch, kv-head) PO2
    exponents [B, Hkv] int32; ``length`` [B] (or scalar) masks the valid
    cache prefix.  Returns output matching q's rank, in q's dtype.  This
    is the second op family beside ``execute_gemm``: the ``oracle``
    backend runs the shape-polymorphic jnp reference, the ``pallas``
    backend the flash-decode TPU kernel (interpret off-TPU); chunked
    launches resolve their KV tile via the ``prefill_attn`` autotune
    shape class.
    """
    backend = get_backend(backend).resolve()
    s = int(k_codes.shape[1])
    block_s = kv_block_size(s, block_s if block_s is not None else 512)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32),
                              (k_codes.shape[0],))
    return backend.kv_attention(
        q, k_codes, v_codes, k_exp.astype(jnp.int32),
        v_exp.astype(jnp.int32), length, block_s=block_s)


def backend_parity_check(dq: DeployedQuantState, x: jax.Array, *,
                         backends=("oracle", "pallas"), reps: int = 1,
                         warmup: int = 1):
    """Run one deployed GEMM through several backends, side by side.

    Returns ``(outs, times_us, bit_equal)``: per-backend outputs,
    per-backend wall-clock (jitted, post-warmup, microseconds), and
    whether every output is bit-identical to the first.  Shared by
    ``benchmarks/kernel_bench.py`` and the dry-run's per-cell
    ``backend_parity`` report so parity is measured one way everywhere.
    """
    import time

    import numpy as np

    outs, times = {}, {}
    for be in backends:
        resolved = get_backend(be)
        f = jax.jit(lambda a, _b=resolved: execute_gemm(dq, a, backend=_b))
        for _ in range(warmup):
            jax.block_until_ready(f(x))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = jax.block_until_ready(f(x))
        times[resolved.name] = (time.perf_counter() - t0) / reps * 1e6
        outs[resolved.name] = out
    vals = list(outs.values())
    bit_equal = all(np.array_equal(np.asarray(vals[0]), np.asarray(v))
                    for v in vals[1:])
    return outs, times, bit_equal


def execute_expert_gemm(dq: DeployedQuantState, x: jax.Array, *,
                        backend=None) -> jax.Array:
    """Stacked expert-bank GEMM: x [E, C, K] against per-expert codes.

    ``dq`` carries a leading expert axis on every data leaf (w_codes
    [E, K, N], ax_exp [E], aw_exp [E, ...], psum_exps [E, n_p, ...] — the
    per-expert exponent banks emitted by ``export_quantized``).  All E
    experts execute as ONE backend op: activations quantize per expert
    (vmapped PO2 shifts), the backend's ``int_expert_gemm`` runs the
    stacked integer GEMM — a single fused ``pallas_call`` whose grid
    carries the expert axis on the Pallas backend, E oracle calls on the
    reference backend — and the INT32 outputs rescale per expert by
    ``2^(ax_exp[e] + aw_exp[e])``.  Bit-identical to slicing expert ``e``
    out of ``dq`` and calling ``execute_gemm`` on it (tests enforce).
    """
    backend = get_backend(backend).resolve()
    spec = dq.spec or QuantConfig.w8a8()
    n_exp = int(dq.w_codes.shape[0])
    k = int(dq.w_codes.shape[-2])
    out_shape = x.shape[:-1] + dq.out_dims
    xc = jax.vmap(
        lambda xe, ae: quantize_activations(xe.reshape(-1, k), ae,
                                            spec.a_bits)
    )(x, dq.ax_exp)
    gs = 1
    if dq.psum_exps is not None:
        n_p = int(dq.psum_exps.shape[1])
        gs = n_p if spec.psum.mode == "psq" else spec.psum.gs
    y = backend.int_expert_gemm(xc, dq.w_codes, dq.psum_exps, gs=gs)
    aw = dq.aw_exp
    aw = aw.reshape(n_exp, 1, -1) if aw.ndim > 1 else aw.reshape(n_exp, 1, 1)
    scale = jnp.exp2((dq.ax_exp.reshape(n_exp, 1, 1) + aw)
                     .astype(jnp.float32))
    return (y.astype(jnp.float32) * scale).astype(x.dtype).reshape(out_shape)
