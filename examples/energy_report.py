"""Reproduce the paper's energy artifacts (Figs 1/5/6, Table IV) as text.

    PYTHONPATH=src python examples/energy_report.py
"""
from benchmarks import (fig1_breakdown, fig5_precision, fig6_energy_gs,
                        table2_area_proxy, table4_llama_energy)

print("=" * 72)
print("Fig 1 — energy breakdown, BERT-Base-128, IS/WS/OS x PSUM width")
print("=" * 72)
fig1_breakdown.run()
print()
print("=" * 72)
print("Fig 5 — normalized WS energy vs PSUM precision (energy only)")
print("=" * 72)
fig5_precision.run(with_accuracy=False)
print()
print("=" * 72)
print("Fig 6 — normalized energy vs gs (3 models, IS + WS)")
print("=" * 72)
fig6_energy_gs.run()
print()
print("=" * 72)
print("Table IV — LLaMA2-7B (P_o=1, P_ci=P_co=32, seq 4096)")
print("=" * 72)
table4_llama_energy.run()
print()
print("=" * 72)
print("Table II — RAE area proxy")
print("=" * 72)
table2_area_proxy.run()
