"""Gate a fresh serving_bench run against the checked-in serving floor.

CI's serve job runs ``serving_bench --smoke --json`` and then this script
with the floor extracted from the committed ``BENCH_serving.json``
(``git show HEAD:BENCH_serving.json``), mirroring
``check_kernel_floor.py`` for the kernel-backend job.  Load records are
matched on (streams, max_batch); each match must hold

  * ``tokens_per_s``  at or above ``floor * slack``          (throughput)
  * ``ttft_p50_ms``   at or below ``floor / slack``          (latency)

and the fresh run's parity record must be all-green (a throughput number
from an engine that diverged from the single-stream oracle is
worthless) — including ``horizon_eq_stepwise``, the fused-decode-vs-
per-token-heartbeat token identity.

The fresh run's ``horizon_sweep`` section is gated internally: the
largest-horizon cell must hold ``tokens_per_s >= min_horizon_speedup *``
the horizon-1 cell of the SAME run (``--min-horizon-speedup``, default
1.0 = no check).  That keeps the on-device decode loop from silently
degrading back to per-token dispatch economics while staying robust to
absolute wall-clock noise — the committed BENCH_serving.json documents
the absolute speedup.

Wall-clock on a shared CI box is noisy, so the default slack is
generous — the gate exists to catch scheduler/prefill regressions that
cost multiples (e.g. re-serializing the chunked prefill), not 10%
jitter.

Exit codes: 0 pass, 1 regression, 2 usage/IO error.  No overlapping
load records is a warning, not a failure (a floor from before a load
cell existed cannot gate it).
"""
import argparse
import json
import sys


def _load_records(payload: dict) -> dict:
    out = {}
    for rec in payload.get("records", []):
        if rec.get("section") != "load":
            continue
        out[(rec.get("streams"), rec.get("max_batch"))] = rec
    return out


def _parity_ok(payload: dict) -> bool:
    for rec in payload.get("records", []):
        if rec.get("section") == "parity":
            return bool(rec.get("batched_eq_single")
                        and rec.get("pallas_eq_oracle")
                        # pre-horizon payloads lack the field; treat as ok
                        and rec.get("horizon_eq_stepwise", True))
    return False


def _sweep_records(payload: dict) -> dict:
    """(streams, max_batch, decode_horizon) -> horizon_sweep record."""
    out = {}
    for rec in payload.get("records", []):
        if rec.get("section") != "horizon_sweep":
            continue
        out[(rec.get("streams"), rec.get("max_batch"),
             rec.get("decode_horizon", 1))] = rec
    return out


def _check_horizon_speedup(new: dict, min_speedup: float,
                           print_fn=print) -> int:
    """Within the NEW run: max-horizon cell vs its own horizon-1 cell."""
    sweep = _sweep_records(new)
    cells = sorted({(s, b) for s, b, _ in sweep})
    if not cells:
        print_fn("floor,WARN,no horizon_sweep records — skipping the "
                 "horizon speedup check")
        return 0
    failures = 0
    for s, b in cells:
        hs = sorted(h for s2, b2, h in sweep if (s2, b2) == (s, b))
        if hs[0] != 1 or len(hs) < 2:
            continue                    # no baseline to compare against
        base = sweep[(s, b, 1)].get("tokens_per_s", 0.0)
        best_h = hs[-1]
        tps = sweep[(s, b, best_h)].get("tokens_per_s", 0.0)
        ratio = tps / base if base else float("inf")
        ok = ratio >= min_speedup
        print_fn(f"floor,{'ok' if ok else 'FAIL'},horizon_speedup,"
                 f"streams={s},max_batch={b},h{best_h}/h1={ratio:.2f} "
                 f"(need >= {min_speedup})")
        failures += 0 if ok else 1
    return failures


def check(new: dict, floor: dict, slack: float, print_fn=print,
          min_horizon_speedup: float = 1.0) -> int:
    if not _parity_ok(new):
        print_fn("floor,FAIL,parity record missing or not green — "
                 "refusing to gate throughput of a diverged engine")
        return 1
    new_recs = _load_records(new)
    floor_recs = _load_records(floor)
    overlap = sorted(set(new_recs) & set(floor_recs))
    failures = _check_horizon_speedup(new, min_horizon_speedup, print_fn)
    if not overlap:
        print_fn("floor,WARN,no overlapping load records — nothing to "
                 "gate (floor predates these load cells?)")
        return 1 if failures else 0
    for key in overlap:
        streams, max_batch = key
        rec, ref = new_recs[key], floor_recs[key]
        tps, tps_need = rec.get("tokens_per_s", 0.0), \
            ref.get("tokens_per_s", 0.0) * slack
        ttft = rec.get("ttft_p50_ms", float("inf"))
        ttft_need = ref.get("ttft_p50_ms", 0.0) / slack
        ok = tps >= tps_need and ttft <= ttft_need
        print_fn(f"floor,{'ok' if ok else 'FAIL'},streams={streams},"
                 f"max_batch={max_batch},"
                 f"tokens_per_s={tps} (floor*slack={tps_need:.1f}),"
                 f"ttft_p50_ms={ttft} (floor/slack={ttft_need:.1f})")
        failures += 0 if ok else 1
    if failures:
        print_fn(f"floor,FAIL,{failures} checks regressed past the "
                 f"serving floor / horizon speedup bar")
        return 1
    print_fn(f"floor,pass,{len(overlap)} load cells within the serving "
             f"floor")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new_json", help="fresh serving_bench --json output")
    ap.add_argument("floor_json",
                    help="committed BENCH_serving.json to gate against")
    ap.add_argument("--slack", type=float, default=0.25,
                    help="required fraction of the floor (default 0.25: "
                         "flag >4x regressions, tolerate shared-box "
                         "timing noise)")
    ap.add_argument("--min-horizon-speedup", type=float, default=1.0,
                    help="required tokens/s ratio of the largest-horizon "
                         "sweep cell over the same run's horizon-1 cell "
                         "(default 1.0: fused decode must at least not "
                         "lose to per-token dispatch)")
    args = ap.parse_args(argv)
    try:
        with open(args.new_json) as f:
            new = json.load(f)
        with open(args.floor_json) as f:
            floor = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"floor,ERROR,{e}")
        return 2
    return check(new, floor, args.slack,
                 min_horizon_speedup=args.min_horizon_speedup)


if __name__ == "__main__":
    raise SystemExit(main())
