"""Jit'd public wrappers around the APSQ Pallas kernel.

Handles padding to block multiples, interpret-mode fallback on CPU, operand
quantization from float, and rescaling of the integer result back to float.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import apsq_matmul_kernel, baseline_matmul_kernel


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def apsq_matmul_int8(
    x_codes: jax.Array,
    w_codes: jax.Array,
    exps: jax.Array,
    *,
    gs: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """INT8 GEMM with Algorithm-1 PSUM handling; returns INT32 [M, N].

    ``n_p`` is taken from ``exps.shape[0]``.  Ragged ``K % n_p != 0`` is
    handled by zero-padding K into a remainder PSUM group (zero codes
    contribute nothing to the final tile's partial sum).  ``exps`` is
    [n_p] (per-tensor) or [n_p, N] (per-channel weight scales).
    """
    if interpret is None:
        interpret = _default_interpret()
    m, k = x_codes.shape
    n = w_codes.shape[1]
    n_p = int(exps.shape[0])
    x_codes, w_codes = ref.pad_ragged_k(x_codes, w_codes, n_p)
    bm, bn = min(block_m, _ceil_mult(m, 8)), min(block_n, _ceil_mult(n, 128))
    xp = _pad_to(x_codes, bm, 1)
    wp = _pad_to(w_codes, 1, bn)
    exps = exps.astype(jnp.int32)
    if exps.ndim == 2:  # pad the column axis alongside w (exponent 0 is id)
        exps = _pad_to(exps, 1, bn)
    out = apsq_matmul_kernel(
        xp, wp, exps,
        n_p=n_p, gs=int(gs), block_m=bm, block_n=bn, interpret=interpret,
    )
    return out[:m, :n]


def baseline_matmul_int8(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    n_p: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """INT32-accumulator W8A8 GEMM baseline; returns INT32 [M, N]."""
    if interpret is None:
        interpret = _default_interpret()
    m, k = x_codes.shape
    n = w_codes.shape[1]
    x_codes, w_codes = ref.pad_ragged_k(x_codes, w_codes, n_p)
    bm, bn = min(block_m, _ceil_mult(m, 8)), min(block_n, _ceil_mult(n, 128))
    xp = _pad_to(x_codes, bm, 1)
    wp = _pad_to(w_codes, 1, bn)
    out = baseline_matmul_kernel(
        xp, wp, n_p=n_p, block_m=bm, block_n=bn, interpret=interpret,
    )
    return out[:m, :n]


def _ceil_mult(x: int, mult: int) -> int:
    """Smallest block size: full dim if < mult else mult (keeps grids tiny
    for unit-test shapes while staying 128-aligned for real ones)."""
    return x if x < mult else mult


def quantize_operands(
    x: jax.Array, w: jax.Array, *, ax: jax.Array | float, aw: jax.Array | float
):
    """Float activations/weights -> INT8 codes with scales ax (per-tensor)
    and aw (per-tensor or per-column [N])."""
    xq = jnp.clip(jnp.round(x / ax), -128, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / aw), -128, 127).astype(jnp.int8)
    return xq, wq


def apsq_matmul_f32(
    x: jax.Array,
    w: jax.Array,
    exps: jax.Array,
    *,
    gs: int,
    ax: jax.Array | float,
    aw: jax.Array | float,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Deployment-path float entry: quantize -> integer kernel -> rescale.

    Output scale is product-scale ``ax * aw`` (aw broadcasts per-column).
    """
    xq, wq = quantize_operands(x, w, ax=ax, aw=aw)
    y = apsq_matmul_int8(
        xq, wq, exps, gs=gs, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )
    return y.astype(jnp.float32) * jnp.asarray(ax, jnp.float32) * jnp.asarray(
        aw, jnp.float32
    )


def calibrate_exps(
    x_codes: jax.Array, w_codes: jax.Array, *, n_p: int, gs: int
) -> jax.Array:
    """Exponent calibration from a sample batch (see ref.choose_exps)."""
    return ref.choose_exps(x_codes, w_codes, n_p=n_p, gs=gs)
