"""QAT integration: calibration over a model, distillation loss, gs sweep.

The paper trains APSQ inside W8A8 QAT guided by a full-precision teacher
(§IV-A).  Here:

  * ``calibrate_model``  — one forward pass over a calibration batch that
    refines every linear's activation & PSUM scales from the *running
    accumulation* statistics (the quantity APSQ quantizes), by re-running
    ``calibrate_dense`` at each quantized linear.  Implemented as a pure
    tree surgery: we intercept ``dense`` via param-tree traversal, which
    keeps the model code untouched.
  * ``distill_loss``     — KL(teacher || student) on logits + CE mix,
    the standard QAT-with-teacher objective.
  * ``gs_sweep``         — train/eval the same model across gs values
    (Table I reproduction harness; used by benchmarks/table1_accuracy).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import QuantConfig, calibrate_dense
from repro.models.config import ModelConfig
from repro.models.model import forward, lm_loss


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def _collect_linears(params, path=()):
    """Yield (path, subtree) for every quantized linear ({'w', 'qp'})."""
    if isinstance(params, dict):
        if "w" in params and "qp" in params:
            yield path, params
        for k, v in params.items():
            if k in ("w", "qp"):
                continue
            yield from _collect_linears(v, path + (k,))


def _tree_get(tree, path):
    for k in path:
        tree = tree[k]
    return tree


def _tree_set(tree, path, value):
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = _tree_set(tree[path[0]], path[1:], value)
    return out


class _CalibTap:
    """Activation-capturing stand-in installed around quantized linears."""

    captured: dict = {}


def calibrate_model(params, cfg: ModelConfig, batch: dict,
                    sample_tokens: int = 512):
    """Refine every quantized linear's (ax, ap) from one forward pass.

    Uses jax's pure callbacks-free approach: run the forward once with
    quantization *disabled* while capturing each linear's input via
    ``jax.experimental.io_callback``-free monkey patching is fragile, so we
    instead exploit the structure: for LSQ the input statistics of layer i
    only weakly depend on upstream quantization, so calibrating from the
    float forward is the standard "one-shot" calibration.  We recompute
    each linear's input by a partial forward — impractical for deep nets —
    so instead we run the quantized forward *with capture enabled* through
    ``capture_scope``.
    """
    from repro.models import common as _common

    taps: dict = {}
    orig_quant_dense = _common.quant_dense

    def capturing_quant_dense(x, w, qp, qcfg):
        # Record a small sample of (x, w) per distinct qp id.  Tracers
        # (scan-over-layers bodies) are skipped — calibrate with
        # ``cfg.scan_layers=False`` to reach every linear.
        key = id(qp.get("ap")) if qp and "ap" in qp else id(qp)
        if key not in taps and not isinstance(x, jax.core.Tracer):
            xs = x.reshape(-1, x.shape[-1])[:sample_tokens]
            taps[key] = (xs, w, qp)
        return orig_quant_dense(x, w, qp, qcfg)

    _common.quant_dense = capturing_quant_dense
    try:
        forward(params, cfg, batch["tokens"],
                embeds=batch.get("embeds"),
                enc_embeds=batch.get("enc_embeds"))
    finally:
        _common.quant_dense = orig_quant_dense

    # Apply calibrate_dense to every captured linear and write back.
    new_params = params
    for path, lin in _collect_linears(params):
        qp = lin["qp"]
        key = id(qp.get("ap")) if "ap" in qp else id(qp)
        if key not in taps:
            continue
        xs, w2d, _ = taps[key]
        new_qp = calibrate_dense(qp, xs, w2d, cfg.quant)
        new_lin = dict(lin)
        new_lin["qp"] = new_qp
        new_params = _tree_set(new_params, path, new_lin)
    return new_params


# ---------------------------------------------------------------------------
# Distillation
# ---------------------------------------------------------------------------

def distill_loss(student_logits: jax.Array, teacher_logits: jax.Array,
                 labels: jax.Array, alpha: float = 0.5,
                 temperature: float = 2.0) -> jax.Array:
    """alpha * KL(teacher || student) * T^2 + (1 - alpha) * CE(labels)."""
    t = temperature
    sl = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    tl = jax.nn.softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    kl = jnp.sum(tl * (jnp.log(jnp.maximum(tl, 1e-20)) - sl), axis=-1)
    ce = lm_loss(student_logits, labels)
    return alpha * jnp.mean(kl) * (t * t) + (1 - alpha) * ce


def make_distill_loss_fn(cfg_student: ModelConfig, cfg_teacher: ModelConfig,
                         teacher_params, alpha: float = 0.5,
                         temperature: float = 2.0):
    """(student_params, batch) -> loss with frozen FP teacher logits."""
    def loss_fn(params, batch):
        s_logits = forward(params, cfg_student, batch["tokens"],
                           embeds=batch.get("embeds"),
                           enc_embeds=batch.get("enc_embeds"))
        t_logits = jax.lax.stop_gradient(
            forward(teacher_params, cfg_teacher, batch["tokens"],
                    embeds=batch.get("embeds"),
                    enc_embeds=batch.get("enc_embeds")))
        return distill_loss(s_logits, t_logits, batch["labels"],
                            alpha, temperature)
    return loss_fn


# ---------------------------------------------------------------------------
# gs sweep harness (Table I)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    gs: int
    mode: str
    final_loss: float
    eval_loss: float


def quant_variants(base: QuantConfig, gs_values=(1, 2, 3, 4),
                   n_p: int = 8) -> dict:
    """Baseline (W8A8, no PSUM quant) + APSQ at each gs + PSQ."""
    out = {"baseline_w8a8": QuantConfig.w8a8()}
    for gs in gs_values:
        out[f"apsq_gs{gs}"] = QuantConfig.apsq(gs=gs, n_p=n_p)
    out["psq"] = QuantConfig.psq(n_p=n_p)
    return out
