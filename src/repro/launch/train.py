"""Training launcher: ``python -m repro.launch.train --arch <id> ...``.

Runs the full production path on whatever devices exist (CPU smoke, one
TPU host, or a multi-host slice — jax.distributed is initialized when the
environment provides coordinator addresses).  Combines:

  config registry -> (optionally reduced) model -> Trainer (microbatching,
  remat, straggler watchdog) -> deterministic data -> async checkpoints
  with resume.

The paper's technique rides on ``--quant apsq --gs 2 --np 8`` — APSQ on
every projection GEMM of any architecture.
"""
from __future__ import annotations

import argparse
import dataclasses
import os


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--quant", default="none",
                    choices=("none", "w8a8", "psq", "apsq"))
    ap.add_argument("--gs", type=int, default=2)
    ap.add_argument("--np", dest="n_p", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--compress-dcn", action="store_true")
    ap.add_argument("--mesh", default="auto",
                    choices=("auto", "single", "multi"))
    args = ap.parse_args()

    if args.mesh == "multi" and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512")

    import jax

    from repro.configs import get_config, get_smoke
    from repro.core import QuantConfig
    from repro.data import DataConfig
    from repro.launch.mesh import make_production_mesh
    from repro.optim import OptimConfig
    from repro.train import TrainConfig, Trainer

    if args.smoke:
        cfg = get_smoke(args.arch)
        if args.quant != "none":
            q = {"apsq": QuantConfig.apsq(gs=args.gs, n_p=args.n_p),
                 "psq": QuantConfig.psq(n_p=args.n_p),
                 "w8a8": QuantConfig.w8a8()}[args.quant]
            cfg = cfg.with_quant(q)
    else:
        cfg = get_config(args.arch, quant=args.quant, gs=args.gs,
                         n_p=args.n_p)

    mesh = None
    if args.mesh != "auto" or len(jax.devices()) > 1:
        try:
            mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        except ValueError:
            mesh = None  # not enough devices; run unsharded

    ocfg = OptimConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 5))
    tcfg = TrainConfig(
        microbatches=args.microbatches, steps=args.steps,
        save_every=args.save_every, ckpt_dir=args.ckpt_dir,
        compress_dcn_grads=args.compress_dcn)
    data = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len,
        global_batch=args.global_batch, frontend=cfg.frontend,
        d_model=cfg.d_model,
        n_frontend_tokens=cfg.n_frontend_tokens or args.seq_len)

    trainer = Trainer(cfg, ocfg, tcfg, mesh=mesh)
    trainer.fit(data)
    print(f"[train] finished {args.steps} steps; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
