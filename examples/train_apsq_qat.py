"""End-to-end driver: QAT-train a ~100M-param LM with APSQ PSUMs.

    PYTHONPATH=src python examples/train_apsq_qat.py \
        --steps 300 --quant apsq --gs 2

Full production path: config -> Trainer (microbatch accumulation, remat,
async checkpoints, SIGTERM emergency save, straggler watchdog) ->
deterministic synthetic corpus -> resume-on-restart.  ``--tiny`` shrinks
the model for fast CPU runs (CI uses it); the default ~100M config is the
assignment's "train ~100M model for a few hundred steps" driver.
"""
import argparse

from repro.core import QuantConfig
from repro.data import DataConfig
from repro.models.config import ModelConfig
from repro.optim import OptimConfig
from repro.train import TrainConfig, Trainer


def model_100m(quant: QuantConfig) -> ModelConfig:
    # ~100M params: 12L, d=768, ffn=2048, 32k vocab (llama-style).
    return ModelConfig(name="apsq-qat-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                       vocab=32000, dtype="float32", quant=quant)


def model_tiny(quant: QuantConfig) -> ModelConfig:
    return ModelConfig(name="apsq-qat-tiny", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab=512, dtype="float32", quant=quant)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quant", default="apsq",
                    choices=("none", "w8a8", "psq", "apsq"))
    ap.add_argument("--gs", type=int, default=2)
    ap.add_argument("--np", dest="n_p", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/apsq_qat_ckpt")
    args = ap.parse_args()

    q = {"none": QuantConfig(),
         "w8a8": QuantConfig.w8a8(),
         "psq": QuantConfig.psq(n_p=args.n_p),
         "apsq": QuantConfig.apsq(gs=args.gs, n_p=args.n_p)}[args.quant]
    cfg = (model_tiny if args.tiny else model_100m)(q)

    trainer = Trainer(
        cfg,
        OptimConfig(lr=3e-4, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps),
        TrainConfig(steps=args.steps, microbatches=args.microbatches,
                    save_every=max(args.steps // 4, 10),
                    log_every=10, ckpt_dir=args.ckpt_dir))
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    trainer.fit(data)
    losses = [m["loss"] for m in trainer.metrics_log]
    if losses:
        print(f"[qat] {cfg.name} quant={args.quant}: "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
