"""Beyond-paper: the PSUM-precision-aware energy model applied to all 10
assigned architectures (prefill 4k + MAC-preserving decode)."""
from repro.configs import ARCH_NAMES, get_config
from repro.energy import AcceleratorConfig, arch_layers, model_energy


def run(print_fn=print):
    out = {}
    for name in ARCH_NAMES:
        cfg = get_config(name)
        layers = arch_layers(cfg, 4096)
        for df, acc in (("WS", AcceleratorConfig()),
                        ("WS-dec", AcceleratorConfig.llm_decode())):
            base = model_energy(layers, acc, "WS", psum_bits=32)
            a = model_energy(layers, acc, "WS", psum_bits=8, gs=2)
            out[(name, df)] = base["total"] / a["total"]
        print_fn(f"arch_energy,{name},"
                 f"prefill4k_saving={1 - 1 / out[(name, 'WS')]:.2%},"
                 f"decode_ratio={out[(name, 'WS-dec')]:.2f}x")
    return out


if __name__ == "__main__":
    run()
