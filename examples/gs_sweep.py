"""Table-I style sweep: QAT the same model at every PSUM strategy.

    PYTHONPATH=src python examples/gs_sweep.py --steps 80

Prints eval loss for baseline W8A8, APSQ gs=1..4, PSQ — the reproduction
of the paper's accuracy-vs-grouping claim (lower = better).
"""
import argparse

from benchmarks.table1_accuracy import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()
    results = run(steps=args.steps)
    print("\nsummary (eval loss, lower=better):")
    for name, ev in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"  {name:16s} {ev:.4f}")


if __name__ == "__main__":
    main()
