"""Quickstart: APSQ in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Quantize one GEMM's partial sums to INT8 with Algorithm 1 (APSQ +
   grouping) and measure the error vs the fp32 result.
2. Run the true-integer Pallas kernel (interpret mode on CPU) and verify
   it agrees bit-exactly with the integer oracle.
3. Ask the paper's analytical accelerator model what that buys in energy.
4. Per-layer policy on a whole model: attention GEMMs at gs=2/n_p=4,
   FFN GEMMs at gs=4/n_p=8 (the RAE reconfigures per layer), capture-based
   calibration, integer export, and deployed serving.
5. Backend selection: the calibrate -> export -> kernel-serving flow.
   Deployed GEMMs dispatch through the ``repro.exec`` registry —
   ``oracle`` (jnp reference), ``pallas`` (the real kernel; interpret
   mode on CPU), ``auto`` (kernel on TPU, oracle elsewhere) — and greedy
   decodes are token-for-token identical across backends.
6. Search a policy: ``repro.search`` walks an architecture's actual GEMM
   inventory (one layer namespace shared with the quantizer), scores
   per-layer (gs, n_p) policies on energy x accuracy, and returns the
   Pareto front.  Full loop:
   ``python -m repro.search.cli --arch tinyllama-1.1b --budget-smoke``.
8. Serve across a mesh: shard the exported code banks + KV pools over a
   "model" axis and decode with INT8-on-the-wire collectives, bit-exact
   vs single-device.  Needs >= 2 devices — rerun with
   ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` to see it.

Block autotuning: every Pallas launch resolves its (block_m, block_n,
exponent layout) per shape class through ``repro.kernels.autotune`` —
decode (M=1) takes a single-row fast-path kernel, prefill gets large
MXU-aligned tiles, MoE expert banks run one fused grid over all experts.
The default is a static heuristic (nothing is ever timed at trace time);
``PYTHONPATH=src python -m repro.kernels.autotune`` measures the real
candidates on this host and caches winners in
``~/.cache/repro-apsq/autotune-v1.json`` (override with
``$REPRO_AUTOTUNE_CACHE``), after which every kernel launch — including
the serving engines below — picks them up automatically.
``python -m repro.kernels.autotune --show`` prints the resolved table.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (QuantConfig, calibrate_dense, quant_dense,
                        quant_params_init)
from repro.energy import AcceleratorConfig, LayerShape, layer_energy
from repro.kernels.apsq_matmul import (apsq_matmul_int8, apsq_matmul_ref,
                                       choose_exps)

key = jax.random.PRNGKey(0)

# --- 1. fake-quant QAT path ------------------------------------------------
x = jax.random.normal(key, (64, 512))                  # activations
w = jax.random.normal(jax.random.fold_in(key, 1), (512, 256)) * 0.05
ref = x @ w

for mode, cfg in [
    ("w8a8 (no psum quant)", QuantConfig.w8a8()),
    ("psq  (independent tiles)", QuantConfig.psq(n_p=8)),
    ("apsq gs=1", QuantConfig.apsq(gs=1, n_p=8)),
    ("apsq gs=2", QuantConfig.apsq(gs=2, n_p=8)),
    ("apsq gs=4", QuantConfig.apsq(gs=4, n_p=8)),
]:
    qp = calibrate_dense(quant_params_init(w, cfg), x, w, cfg)
    y = quant_dense(x, w, qp, cfg)
    rel = float(jnp.mean(jnp.abs(y - ref)) / jnp.mean(jnp.abs(ref)))
    print(f"{mode:28s} rel-err {rel:.4f}")

# --- 2. true-integer deployment kernel --------------------------------------
xq = jax.random.randint(key, (64, 512), -128, 128, jnp.int8)
wq = jax.random.randint(jax.random.fold_in(key, 2), (512, 256), -128, 128,
                        jnp.int8)
exps = choose_exps(xq, wq, n_p=8, gs=2)
kern = apsq_matmul_int8(xq, wq, exps, gs=2, interpret=True)
oracle = apsq_matmul_ref(xq, wq, exps, n_p=8, gs=2)
print(f"\nPallas kernel bit-exact vs oracle: "
      f"{bool(jnp.all(kern == oracle))}")

# --- 3. what it buys (paper eqs 1-6) ----------------------------------------
layer = LayerShape("ffn", tokens=128, c_i=768, c_o=3072)
acc = AcceleratorConfig()
e32 = layer_energy(layer, acc, "WS", psum_bits=32)
e8 = layer_energy(layer, acc, "WS", psum_bits=8, gs=2)
print(f"\nBERT FFN layer, WS dataflow: INT32-PSUM {e32['total']:.2e} J "
      f"-> APSQ INT8 {e8['total']:.2e} J "
      f"({100 * (1 - e8['total'] / e32['total']):.0f}% saved)")

# --- 4. per-layer policy -> calibrate -> export -> integer serving ----------
from repro.models.config import ModelConfig
from repro.models.model import forward, init_lm
from repro.quant import QuantPolicy, calibrate_model, export_quantized
from repro.serving import Request, ServingEngine

policy = QuantPolicy.of(
    ("*.mix.*", QuantConfig.apsq(gs=2, n_p=4)),   # attention projections
    ("*.ffn.*", QuantConfig.apsq(gs=4, n_p=8)),   # FFN projections
    default=QuantConfig.w8a8(),                   # everything else W8A8
)
cfg = ModelConfig(name="quickstart", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  dtype="float32", scan_layers=False).with_quant(policy)
params = init_lm(jax.random.PRNGKey(3), cfg)
wq_spec = params["units"]["u0"]["0"]["mix"]["wq"]["qp"].spec
wi_spec = params["units"]["u0"]["0"]["ffn"]["wi"]["qp"].spec
print(f"\nper-layer policy: mix.wq -> gs={wq_spec.psum.gs} "
      f"n_p={wq_spec.psum.n_p}; ffn.wi -> gs={wi_spec.psum.gs} "
      f"n_p={wi_spec.psum.n_p}")

tok = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab)
params = calibrate_model(params, cfg, {"tokens": tok})   # capture-based
logits = forward(params, cfg, tok)
print(f"calibrated QAT forward: {logits.shape}, "
      f"finite={bool(jnp.all(jnp.isfinite(logits)))}")

deploy, report = export_quantized(params)
int8_total = sum(r["int8_bytes"] * r["count"] for r in report.values())
print(f"export: {len(report)} layer groups, {int8_total / 1024:.0f} KiB of "
      f"INT8 weight codes")
engine = ServingEngine(deploy, cfg, max_batch=2, cache_len=64,
                       prefill_chunk=8)
done = engine.run([Request(uid=0, tokens=np.arange(6) % cfg.vocab,
                           max_new_tokens=8)])
print(f"integer-deployed engine decoded: {done[0].out}")

# --- 5. backend selection: serve the calibrated model through the kernel ----
# ``from_exported`` exports and serves in one call; ``backend=`` picks the
# executor.  "auto" (default) runs the Pallas kernel on TPU and the
# bit-identical jnp oracle elsewhere; pinning "pallas" on CPU exercises
# the kernel in interpret mode — same integers, token-for-token.
prompt = np.arange(6) % cfg.vocab
decodes = {}
for backend in ("oracle", "pallas"):
    eng = ServingEngine.from_exported(params, cfg, max_batch=1, cache_len=64,
                                      prefill_chunk=8, backend=backend)
    decodes[backend] = eng.run([Request(uid=1, tokens=prompt,
                                        max_new_tokens=6)])[0].out
print(f"\nkernel-served decode ({'==' if decodes['oracle'] == decodes['pallas'] else '!='} oracle): "
      f"{decodes['pallas']}")
assert decodes["oracle"] == decodes["pallas"]

# --- 6. search a policy: energy x accuracy co-exploration --------------------
# ``repro.search.inventory`` names every GEMM of an architecture with the
# SAME stable names the quantizer uses, so one QuantPolicy drives both the
# analytical energy model (full-size shapes) and the fake-quant accuracy
# proxy.  Here: score three policies on TinyLlama's real GEMM walk; the
# CLI (see module docstring) runs the full candidate-generation + Pareto +
# calibrate->export->pallas round-trip loop.
from repro.configs import get_config
from repro.search import energy_report, model_inventory

cfg_full = get_config("tinyllama-1.1b")
inv = model_inventory(cfg_full, seq_len=4096)
print(f"\npolicy search: {len(inv)} named GEMMs on {cfg_full.name} "
      f"(e.g. {inv[0].shape.name})")
for pname, pol in [
    ("uniform w8a8", QuantPolicy.uniform(QuantConfig.w8a8())),
    ("uniform apsq(gs=2)", QuantPolicy.uniform(QuantConfig.apsq(gs=2))),
    ("ffn-only apsq", QuantPolicy.of(("*.ffn.*", QuantConfig.apsq(gs=2)),
                                     default=QuantConfig.w8a8())),
]:
    r = energy_report(cfg_full, pol, inventory=inv)
    print(f"  {pname:20s} E={r['energy_j']:.2e} J "
          f"(saves {r['saving']:.0%} vs INT32 PSUM)")

# --- 7. serve many streams: continuous batching over INT8 KV pages ----------
# The production serving path: calibrate -> export -> PagedServingEngine.
# Every attention layer's cache is a pool of fixed-size INT8 pages with
# power-of-two scales (the paper's shift-only dequant argument applied to
# the KV cache); a host-side scheduler admits requests as slots and pages
# free up, grows each stream's page list on demand, and — when the pool
# runs dry — preempts the latest-admitted stream and resumes it later
# with bit-identical output.  Prompts prefill CHUNKED: up to
# ``prefill_chunk`` tokens per forward (every GEMM at m=chunk, attention
# with an in-chunk causal mask against the paged cache), writing the same
# INT8 codes and exponents the old token-by-token scan wrote — bit
# identical, just ~chunk-times fewer dispatches, so TTFT drops.  Each
# engine step spends a ``prefill_token_budget`` on pending prompts before
# decoding all in-flight slots, so long prompts interleave with decodes
# instead of stalling them; raise ``prefill_chunk`` (and the budget) for
# prompt-heavy loads.  Decode attention reads go through the second
# ``repro.exec`` op family (``kv_attention``: Pallas flash-decode kernel
# on TPU, jnp oracle elsewhere — the chunk rides its query-row axis, the
# "prefill_attn" autotune class), so weights AND cache are integer end to
# end.  Decode itself runs FUSED: every heartbeat scans up to
# ``decode_horizon`` (pow2, default 8) decode steps inside one jitted
# ``lax.scan`` — sampling, per-stream EOS/max-token stops, position
# advance and KV writes all on device, one host sync per macro-step
# draining a [batch, horizon] token block.  The scheduler pre-reserves
# each stream's pages over the horizon and shrinks a stream's budget
# (never preempting) when the pool is tight.  Raise the horizon when
# decode is dispatch-bound (host round-trips per token dominate — the
# usual case once kernels are fast); keep it at 1 for very tight page
# pools or a strict per-token latency SLO, since tokens surface to the
# host a macro-step at a time.  H fused steps stay token- AND
# KV-bit-identical to H single steps, so the parity story is unchanged.
# ``benchmarks/serving_bench.py`` drives this engine with hundreds of
# Poisson-arrival streams and reports tokens/s, prefill tokens/s,
# p50/p99, a host-overhead breakdown, and a --decode-horizon sweep;
# ``benchmarks/check_serving_floor.py`` holds CI to the committed
# floors plus the fused-vs-per-token speedup.
from repro.serving import PagedServingEngine

paged = PagedServingEngine.from_exported(
    params, cfg, max_batch=4, page_size=8, n_pages=33, prefill_chunk=8,
    decode_horizon=4)
streams = [Request(uid=i, tokens=(np.arange(5 + i) * 3) % cfg.vocab,
                   max_new_tokens=6) for i in range(8)]
done = paged.run(streams)
solo = PagedServingEngine.from_exported(
    params, cfg, max_batch=1, page_size=8, n_pages=33, prefill_chunk=8,
    decode_horizon=1)                      # per-token heartbeat reference
ref = solo.run([Request(uid=0, tokens=(np.arange(5) * 3) % cfg.vocab,
                        max_new_tokens=6)])[0].out
batched0 = next(r.out for r in done if r.uid == 0)
print(f"\npaged INT8 serving: {len(done)} streams on 4 slots "
      f"({paged.sched.stats.admitted} admissions, "
      f"{paged.sched.stats.preempted} preemptions, "
      f"{paged.decode_dispatches} fused decode launches), "
      f"batched h4 == single-stream h1: {batched0 == ref}")
assert batched0 == ref

# --- 8. serve across a mesh: tensor/expert-parallel integer serving ----------
# ``mesh=`` shards the SAME exported tree over the "model" axis —
# ``repro.dist.tp`` places each code bank by its Algorithm-1 mode (K by
# whole PSUM tiles for PSQ/W8A8 so int32 partials combine exactly; N for
# APSQ, whose group-start chain is sequential along K; the expert axis
# for MoE banks) and the KV pools over kv-heads.  Collectives move INT8
# codes, not fp32 partials (``wire="fp32"`` is the parity-debug path —
# same tokens, ~4x the bytes; ``engine.shard_plan`` + ``wire_report``
# price every collective analytically, see benchmarks/dist_bench.py).
# Recipe: calibrate -> from_exported(mesh=...) -> decode -> compare
# against the single-device engine.  Same integers, token-for-token.
if len(jax.devices()) >= 2:
    from repro.dist import wire_report
    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh((1, 2))               # ("data", "model")
    sharded = PagedServingEngine.from_exported(
        params, cfg, max_batch=1, page_size=8, n_pages=33,
        prefill_chunk=8, mesh=mesh, wire="int8")
    out = sharded.run([Request(uid=0, tokens=(np.arange(5) * 3) % cfg.vocab,
                               max_new_tokens=6)])[0].out
    wr = wire_report(sharded.shard_plan, m=1)
    print(f"mesh-served decode == single-device: {out == ref}; "
          f"switchable collectives int8/fp32 = "
          f"{wr['switchable']['ratio'] or 1.0:.1f}x fewer bytes")
    assert out == ref
else:
    print("\nmesh serving: skipped (1 device; set XLA_FLAGS="
          "--xla_force_host_platform_device_count=2 to run step 8)")
