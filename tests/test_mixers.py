"""Time-mix blocks: RWKV6 (scan == chunked == stepwise), RG-LRU
(scan == stepwise), MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import init_moe, moe_ffn
from repro.models.rglru import init_rglru_block, rglru_block
from repro.models.rwkv import (
    init_rwkv_channel_mix,
    init_rwkv_time_mix,
    rwkv_channel_mix,
    rwkv_time_mix,
)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_rwkv_chunked_matches_scan(chunk):
    p = init_rwkv_time_mix(KEY, 32, 2, 16, jnp.float32)
    x = jax.random.normal(KEY, (2, 20, 32)) * 0.1
    y1, s1 = rwkv_time_mix(p, x, n_heads=2, head_dim=16, impl="scan")
    y2, s2 = rwkv_time_mix(p, x, n_heads=2, head_dim=16, impl="chunked",
                           wkv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1["wkv"]), np.asarray(s2["wkv"]),
                               rtol=1e-3, atol=1e-4)


def test_rwkv_stepwise_decode_matches_full():
    p = init_rwkv_time_mix(KEY, 32, 2, 16, jnp.float32)
    x = jax.random.normal(KEY, (1, 12, 32)) * 0.1
    y_full, _ = rwkv_time_mix(p, x, n_heads=2, head_dim=16, impl="scan")
    st, ys = None, []
    for t in range(12):
        yt, st = rwkv_time_mix(p, x[:, t:t + 1], n_heads=2, head_dim=16,
                               impl="scan", state=st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-3, atol=1e-4)


def test_rwkv_channel_mix_stepwise():
    p = init_rwkv_channel_mix(KEY, 32, 64, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 32))
    y_full, _ = rwkv_channel_mix(p, x)
    st, ys = None, []
    for t in range(8):
        yt, st = rwkv_channel_mix(p, x[:, t:t + 1], state=st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-5)


def test_rglru_stepwise_decode_matches_scan():
    p = init_rglru_block(KEY, 32, 64, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, 32)) * 0.3
    y_full, _ = rglru_block(p, x)
    st, ys = None, []
    for t in range(16):
        yt, st = rglru_block(p, x[:, t:t + 1], state=st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-3, atol=1e-4)


def test_rglru_decay_in_unit_interval():
    p = init_rglru_block(KEY, 16, 32, jnp.float32)
    lam = np.asarray(jax.nn.softplus(p["lam"]))
    a_at_r1 = np.exp(-8.0 * lam)
    assert np.all(a_at_r1 > 0.85) and np.all(a_at_r1 < 0.9995)


def test_moe_output_shape_and_finiteness():
    p = init_moe(KEY, 32, 64, 8, 2, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, 32))
    y = moe_ffn(p, x, n_experts=8, top_k=2, capacity_factor=2.0)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_capacity_drops_tokens_gracefully():
    """cap factor << 1 drops tokens (output partial/zero) but stays finite."""
    p = init_moe(KEY, 32, 64, 4, 2, jnp.float32)
    x = jax.random.normal(KEY, (1, 32, 32))
    y_lo = moe_ffn(p, x, n_experts=4, top_k=2, capacity_factor=0.1)
    y_hi = moe_ffn(p, x, n_experts=4, top_k=2, capacity_factor=4.0)
    assert bool(jnp.all(jnp.isfinite(y_lo)))
    # low capacity must change (drop) some outputs
    assert float(jnp.mean(jnp.abs(y_lo - y_hi))) > 1e-6


def test_moe_local_expert_partition_sums_to_full():
    """EP invariant: running each expert shard locally and summing equals
    the single-shard full-expert run (psum emulation)."""
    p = init_moe(KEY, 16, 32, 4, 2, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 16))
    full = moe_ffn(p, x, n_experts=4, top_k=2, capacity_factor=4.0)
    parts = []
    for off in (0, 2):
        local = dict(p)  # shard_map slices expert weights; emulate it
        for k in ("wi", "wg", "wo"):
            local[k] = p[k][off:off + 2]
        parts.append(moe_ffn(local, x, n_experts=4, top_k=2,
                             capacity_factor=4.0, expert_offset=off,
                             n_local_experts=2))
    np.testing.assert_allclose(np.asarray(parts[0] + parts[1]),
                               np.asarray(full), rtol=1e-4, atol=1e-5)


def test_moe_grads_flow_to_router_and_experts():
    p = init_moe(KEY, 16, 32, 4, 2, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 16))

    def loss(p):
        return jnp.sum(jnp.square(
            moe_ffn(p, x, n_experts=4, top_k=2, capacity_factor=4.0)))

    g = jax.grad(loss)(p)
    assert float(jnp.sum(jnp.abs(g["router"]["w"]))) > 0
    assert float(jnp.sum(jnp.abs(g["wi"]))) > 0
