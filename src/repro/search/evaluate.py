"""Policy evaluation: energy (analytical) x accuracy (fake-quant proxy).

Shared by ``repro.search.driver`` (the co-exploration loop),
``repro.search.cli``, and ``launch/dryrun.py`` (the ``--quant-policy``
sweep and ``--backend-parity`` cell reports import ``describe_policy`` /
``backend_parity_report`` from here) — one implementation of "what does
this policy cost and how wrong is it" for every surface.

Two axes, both cheap enough to run per candidate:

  * ``energy_report``  — the paper's analytical accelerator model (eqs
    1-6) over the architecture's *full-size* GEMM inventory, with each
    layer's (gs, psum_bits, n_p) resolved from the policy
    (``inventory.energy_specs``) — heterogeneous per-layer energy, scored
    against the INT32-PSUM baseline.
  * ``accuracy_proxy`` — fake-quant forward error vs the fp32 oracle on a
    calibration batch, at the arch's *smoke-scale* sibling (same family,
    CPU-sized).  Calibration is the capture-based ``calibrate_model``
    (the same taps QAT uses), so PSUM scales are data-driven, not
    generic — exactly the error the deployed integer path inherits.

``roundtrip_report`` proves a searched policy is *servable*: calibrate ->
``export_quantized`` -> execute through the Pallas kernel vs the jnp
oracle (GEMM-level bit parity on an exported layer + greedy decode parity
through ``ServingEngine``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.energy import AcceleratorConfig, model_energy
from repro.models.config import ModelConfig

from .inventory import energy_specs, model_inventory


# ---------------------------------------------------------------------------
# Policy description + backend parity (used by launch/dryrun.py cell reports)
# ---------------------------------------------------------------------------

def describe_policy(quant) -> list:
    """Human-readable rule list for a QuantPolicy (JSON-report friendly)."""
    def one(cfg):
        if cfg is None:
            return "float"
        if not cfg.enabled:
            return "disabled"
        if cfg.psum.mode == "none":
            return f"w{cfg.w_bits}a{cfg.a_bits}"
        return (f"{cfg.psum.mode}(gs={cfg.psum.gs},n_p={cfg.psum.n_p},"
                f"bits={cfg.psum.bits})")

    rules = [[r.pattern, one(r.config)]
             for r in getattr(quant, "rules", ())]
    rules.append(["<default>", one(getattr(quant, "default", quant))])
    return rules


def policy_sweep(arg: str) -> list:
    """Resolve a ``--quant-policy`` argument to ``[(label, policy)]``.

    ``arg`` is a preset name from ``repro.quant.policy_presets`` or
    ``'all'`` for the whole registry — the sweep resolution shared by
    ``launch/dryrun.py`` and the search CLI.
    """
    from repro.quant import policy_presets

    presets = policy_presets()
    names = sorted(presets) if arg == "all" else [arg]
    try:
        return [(f"policy_{n}", presets[n]) for n in names]
    except KeyError:
        raise KeyError(f"unknown --quant-policy {arg!r}; "
                       f"known: {sorted(presets)} or 'all'") from None


def backend_parity_report(cfg: ModelConfig, m: int = 8) -> dict:
    """Oracle-vs-pallas execution check at the arch's GEMM shape.

    Exports one calibrated [d_model, d_model] linear under the cfg's
    policy and runs it through ``repro.exec.backend_parity_check``
    (pallas in interpret mode off-TPU) — the side-by-side parity +
    wall-clock the roofline table reports next to each quantized cell.
    """
    from repro.core import quant_params_init, calibrate_dense
    from repro.exec import backend_parity_check
    from repro.quant.export import export_quantized
    from repro.quant.policy import resolve_quant

    # Probe the policy at representative layer names and prefer a
    # PSUM-quantized resolution — a sweep like "ffn_only" must be
    # parity-checked on the APSQ path it exists to measure, not on
    # whatever plain-W8A8 config the first attention layer resolves to.
    probe, resolved = None, None
    for name in ("unit.0.mix.wq", "unit.0.ffn.wi", "rem.0.mix.wq",
                 "encoder.unit.0.mix.wq", "head"):
        r = resolve_quant(cfg.policy, name)
        if r is None:
            continue
        if resolved is None or (resolved.psum.mode == "none"
                                and r.psum.mode != "none"):
            probe, resolved = name, r
        if resolved.psum.mode != "none":
            break
    if resolved is None:
        return {"skipped": "no quantized layers under this policy"}
    k = min(cfg.d_model, 512)  # representative reduction dim, CPU-cheap
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, k)) * 0.05
    qp = calibrate_dense(quant_params_init(w, resolved, name=probe), x, w)
    dep, _ = export_quantized({"lin": {"w": w, "qp": qp}})
    _, times, bit_equal = backend_parity_check(dep["lin"]["qp"], x)
    return {"bit_equal": bit_equal, "layer": probe, "shape": [m, k, k],
            "mode": resolved.psum.mode, "gs": resolved.psum.gs,
            "n_p": resolved.psum.n_p,
            **{f"{name}_us": round(t, 1) for name, t in times.items()}}


# ---------------------------------------------------------------------------
# Energy axis
# ---------------------------------------------------------------------------

def energy_report(cfg: ModelConfig, policy, *, seq_len: int = 4096,
                  stage: str = "prefill", dataflow: str = "WS",
                  acc: AcceleratorConfig | None = None,
                  inventory: list | None = None) -> dict:
    """Heterogeneous per-layer energy of ``policy`` on ``cfg``'s GEMMs.

    Returns total/psum energy under the policy, the INT32-PSUM baseline,
    and the fractional saving — the energy coordinate of one search point.
    Pass ``inventory`` to reuse a precomputed walk across candidates.
    """
    if acc is None:
        acc = (AcceleratorConfig.llm_decode() if stage == "decode"
               else AcceleratorConfig())
    if inventory is None:
        inventory = model_inventory(cfg, seq_len, stage)
    shapes = [e.shape for e in inventory]
    base = model_energy(shapes, acc, dataflow, psum_bits=32)
    e = model_energy(energy_specs(inventory, policy, acc), acc, dataflow)
    return {
        "energy_j": e["total"], "psum_j": e["psum"],
        "baseline_j": base["total"],
        "saving": 1.0 - e["total"] / base["total"],
        "dataflow": dataflow, "seq_len": seq_len, "stage": stage,
    }


# ---------------------------------------------------------------------------
# Accuracy axis (fake-quant forward vs fp32 oracle)
# ---------------------------------------------------------------------------

def make_eval_batch(cfg: ModelConfig, batch: int = 2, seq: int = 32,
                    seed: int = 0) -> dict:
    """Calibration/eval token batch for the accuracy proxy."""
    key = jax.random.PRNGKey(seed)
    out = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab)}
    if cfg.encdec:
        out["enc_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), (batch, seq, cfg.d_model)) * 0.1
    if cfg.frontend == "vision":
        out["embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.1
    return out


def oracle_logits(cfg: ModelConfig, batch: dict, seed: int = 0):
    """fp32 logits of the *unquantized* model at the shared init."""
    from repro.models.model import forward, init_lm

    cfg_f = cfg.with_quant(None) if cfg.policy is not None else cfg
    params = init_lm(jax.random.PRNGKey(seed), cfg_f)
    return forward(params, cfg_f, batch["tokens"],
                   embeds=batch.get("embeds"),
                   enc_embeds=batch.get("enc_embeds"))


def accuracy_proxy(cfg: ModelConfig, policy, batch: dict,
                   ref_logits=None, seed: int = 0) -> dict:
    """Calibrated fake-quant forward error vs the fp32 oracle.

    Init under the policy shares the float weights with the oracle (the
    quantizer state is derived from the weights, not the PRNG), so the
    error is purely the policy's quantization noise.  Returns the scalar
    ``error`` (relative L1 on logits) plus top-1 agreement and KL — the
    accuracy coordinate of one search point.
    """
    from repro.models.model import forward, init_lm
    from repro.quant.qat import calibrate_model

    cfg_q = cfg.with_quant(policy)
    params = init_lm(jax.random.PRNGKey(seed), cfg_q)
    params = calibrate_model(params, cfg_q, batch)
    logits = forward(params, cfg_q, batch["tokens"],
                     embeds=batch.get("embeds"),
                     enc_embeds=batch.get("enc_embeds"))
    if ref_logits is None:
        ref_logits = oracle_logits(cfg, batch, seed)
    lf = ref_logits.astype(jnp.float32)
    lq = logits.astype(jnp.float32)
    rel = float(jnp.mean(jnp.abs(lq - lf)) /
                jnp.maximum(jnp.mean(jnp.abs(lf)), 1e-12))
    top1 = float(jnp.mean((jnp.argmax(lq, -1) == jnp.argmax(lf, -1))
                          .astype(jnp.float32)))
    pf = jax.nn.softmax(lf, -1)
    kl = float(jnp.mean(jnp.sum(
        pf * (jax.nn.log_softmax(lf, -1) - jax.nn.log_softmax(lq, -1)), -1)))
    return {"error": rel, "top1_agreement": top1, "kl": kl}


# ---------------------------------------------------------------------------
# Round trip: searched policy -> calibrate -> export -> kernel serving
# ---------------------------------------------------------------------------

def roundtrip_report(cfg: ModelConfig, policy, batch: dict,
                     seed: int = 0, max_new_tokens: int = 6) -> dict:
    """Prove a searched policy is servable on the integer path.

    calibrate -> ``export_quantized`` -> (a) GEMM-level oracle-vs-pallas
    bit parity on an exported PSUM-quantized layer, (b) greedy decode
    parity through ``ServingEngine`` pinned to each backend.
    """
    from repro.core import DeployedQuantState
    from repro.exec import backend_parity_check
    from repro.models.model import init_lm
    from repro.quant.export import export_quantized
    from repro.quant.qat import calibrate_model
    from repro.serving import Request, ServingEngine

    cfg_q = cfg.with_quant(policy)
    params = init_lm(jax.random.PRNGKey(seed), cfg_q)
    params = calibrate_model(params, cfg_q, batch)
    deploy, export_rep = export_quantized(params)

    # (a) bit parity on a deployed linear — prefer a PSUM-quantized one
    # (the APSQ kernel path), fall back to plain W8A8 codes.
    def find_deployed(tree, require_psum):
        if isinstance(tree, DeployedQuantState):
            ok = tree.w_codes.ndim == 2 and (
                tree.psum_exps is not None or not require_psum)
            return tree if ok else None
        if isinstance(tree, dict):
            for v in tree.values():
                hit = find_deployed(v, require_psum)
                if hit is not None:
                    return hit
        return None

    report: dict = {"n_exported_layers": len(export_rep)}
    dq = (find_deployed(deploy, True) or find_deployed(deploy, False))
    if dq is not None:
        k = int(dq.w_codes.shape[0])
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, k))
        _, times, bit_equal = backend_parity_check(dq, x)
        report["gemm_parity"] = {
            "layer": dq.name, "bit_equal": bool(bit_equal),
            **{f"{n}_us": round(t, 1) for n, t in times.items()}}

    # (b) greedy decode parity: oracle vs pallas, token for token
    prompt = np.asarray(batch["tokens"])[0, :8].astype(np.int64)
    decodes = {}
    for backend in ("oracle", "pallas"):
        eng = ServingEngine(deploy, cfg_q, max_batch=1, cache_len=64,
                            prefill_chunk=8, backend=backend)
        done = eng.run([Request(uid=0, tokens=prompt,
                                max_new_tokens=max_new_tokens)])
        decodes[backend] = list(done[0].out)
    report["decode"] = decodes
    report["serving_parity"] = decodes["oracle"] == decodes["pallas"]
    report["ok"] = bool(report.get("serving_parity")
                        and report.get("gemm_parity", {}).get("bit_equal",
                                                             True))
    return report
