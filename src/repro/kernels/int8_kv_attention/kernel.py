"""Pallas TPU kernel: decode attention over an INT8 KV cache (PO2 scales).

Decode attention is HBM-bandwidth-bound (every decode cell in §Roofline):
the whole KV cache streams through the chip once per token.  Storing the
cache as INT8 codes with power-of-two per-(batch, head) scales halves the
streamed bytes; the dequantization is a multiply by 2^e folded into the
score scale (the RAE shifter argument of §II-B, applied to serving).

Grid: ``(B, Hkv, S / block_s)`` — batch and kv-head parallel, the KV
sequence dimension sequential with an online-softmax VMEM scratch carry
(m, l, acc), exactly the flash-decode structure:

  * q tile  [G, hd]          VMEM (fp32)
  * k tile  [block_s, hd]    VMEM (int8)   <- the bandwidth win
  * v tile  [block_s, hd]    VMEM (int8)
  * exps    SMEM scalars (shift exponents per (b, h))
  * scratch m [G], l [G], acc [G, hd] fp32

Validated against ``ref.int8_kv_attention_ref`` in interpret mode
(tests/test_kernels_kv.py) and within quantization tolerance of the fp32
attention reference.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kv_attn_kernel(kexp_ref, vexp_ref, len_ref, q_ref, k_ref, v_ref,
                    out_ref, m_ref, l_ref, acc_ref, *,
                    n_blocks: int, block_s: int, scale: float,
                    chunk: int, group: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                       # [chunk * G, hd] fp32
    k = k_ref[0, :, 0].astype(jnp.float32)  # [block_s, hd] int8 codes
    v = v_ref[0, :, 0].astype(jnp.float32)
    k_scale = jnp.exp2(kexp_ref[b, h].astype(jnp.float32))
    v_scale = jnp.exp2(vexp_ref[b, h].astype(jnp.float32))

    # scores over codes; the PO2 dequant folds into the softmax scale.
    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    sc = sc * (scale * k_scale)           # [chunk * G, block_s]
    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
    # Causal over the chunk: query row r is chunk token t = r // G, whose
    # cache position is len - chunk + t; it sees positions < that + 1.
    # chunk == 1 reduces to the decode mask (pos < len).
    row_t = jax.lax.broadcasted_iota(jnp.int32, sc.shape, 0) // group
    sc = jnp.where(pos < len_ref[b] - chunk + 1 + row_t, sc, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=1))
    p = jnp.exp(sc - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv * v_scale
    m_ref[...] = m_new

    @pl.when(s == n_blocks - 1)
    def _done():
        out_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)[:, None])


def _compiler_params():
    sem = ("parallel", "parallel", "arbitrary")
    try:
        return pltpu.CompilerParams(dimension_semantics=sem)
    except AttributeError:  # older jax
        return pltpu.TPUCompilerParams(dimension_semantics=sem)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "interpret"))
def int8_kv_attention_kernel(
    q: jax.Array,        # [B, Hq, hd] or [B, C, Hq, hd] fp32
    k_codes: jax.Array,  # [B, S, Hkv, hd] int8
    v_codes: jax.Array,  # [B, S, Hkv, hd] int8
    k_exp: jax.Array,    # [B, Hkv] int32
    v_exp: jax.Array,    # [B, Hkv] int32
    length: jax.Array,   # [B] int32 valid cache length
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """3D q: one decode row.  4D q: a [chunk] of causal prefill rows whose
    last row sits at cache position ``length - 1`` (same flash-decode
    grid; the chunk rides the query-row axis of the q tile, so the MXU
    sees ``chunk * G`` score rows per (b, h) instead of ``G``)."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, C, Hq, hd = q.shape
    S, Hkv = k_codes.shape[1], k_codes.shape[2]
    G = Hq // Hkv
    CG = C * G
    assert S % block_s == 0, (S, block_s)
    n_blocks = S // block_s
    scale = 1.0 / math.sqrt(hd)

    # [B, C, Hkv, G, hd] -> [B, Hkv, C*G, hd]: all of a kv-head's chunk
    # rows land in one q tile.
    qr = jnp.moveaxis(q.reshape(B, C, Hkv, G, hd).astype(jnp.float32),
                      1, 2).reshape(B, Hkv, CG, hd)
    grid = (B, Hkv, n_blocks)
    out = pl.pallas_call(
        functools.partial(_kv_attn_kernel, n_blocks=n_blocks,
                          block_s=block_s, scale=scale, chunk=C, group=G),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # k_exp
            pl.BlockSpec(memory_space=pltpu.SMEM),   # v_exp
            pl.BlockSpec(memory_space=pltpu.SMEM),   # length
            pl.BlockSpec((1, 1, CG, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, CG, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, CG, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((CG,), jnp.float32),
            pltpu.VMEM((CG,), jnp.float32),
            pltpu.VMEM((CG, hd), jnp.float32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(k_exp, v_exp, length, qr, k_codes, v_codes)
    out = jnp.moveaxis(out.reshape(B, Hkv, C, G, hd),
                       2, 1).reshape(B, C, Hq, hd)
    return out[:, 0] if squeeze else out
