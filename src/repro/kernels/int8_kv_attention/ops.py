"""Jit'd public wrappers for the INT8-KV decode attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import int8_kv_attention_kernel
from .ref import quantize_kv_po2


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def int8_kv_attention(
    q: jax.Array,        # [B, Hq, hd] decode or [B, C, Hq, hd] chunk
    k_codes: jax.Array,  # [B, S, Hkv, hd] int8
    v_codes: jax.Array,
    k_exp: jax.Array,    # [B, Hkv] int32
    v_exp: jax.Array,
    length: jax.Array | int,
    *,
    block_s: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Attention over an INT8 cache, matching q's rank.

    3D q is the decode form (one row per batch); 4D q is a prefill chunk
    of C causal rows ending at cache position ``length - 1``.  Returns
    [B, Hq, hd] / [B, C, Hq, hd] in q's dtype.
    """
    if interpret is None:
        interpret = _default_interpret()
    B, S = k_codes.shape[:2]
    block_s = min(block_s, S)
    if S % block_s:
        raise ValueError(f"S={S} not divisible by block_s={block_s}")
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    out = int8_kv_attention_kernel(
        q, k_codes, v_codes, k_exp.astype(jnp.int32),
        v_exp.astype(jnp.int32), length, block_s=block_s,
        interpret=interpret)
    return out.astype(q.dtype)


def int8_kv_attention_f32(q, k, v, length, *, block_s: int = 512,
                          interpret: bool | None = None):
    """Float entry: quantize the cache (PO2) then run the kernel."""
    k_codes, k_exp = quantize_kv_po2(k)
    v_codes, v_exp = quantize_kv_po2(v)
    return int8_kv_attention(q, k_codes, v_codes, k_exp, v_exp, length,
                             block_s=block_s, interpret=interpret)


def cache_bytes(B: int, S: int, Hkv: int, hd: int) -> dict:
    """The bandwidth story: INT8 cache vs bf16 per decode step."""
    return {
        "int8": B * S * Hkv * hd * 2 * 1 + B * Hkv * 2 * 4,  # + exps
        "bf16": B * S * Hkv * hd * 2 * 2,
    }
