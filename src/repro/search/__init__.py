"""repro.search — per-layer (gs, n_p) policy co-exploration (Pareto).

The subsystem PR 1's ``QuantPolicy`` and PR 2's execution backends
unlock: generate candidate per-layer policies from a model's actual GEMM
inventory, score each on (analytical energy, fake-quant accuracy proxy),
return the Pareto front, and prove the winner serves through
calibrate -> export -> Pallas.

    from repro.search import SearchBudget, run_search
    result = run_search("tinyllama-1.1b", SearchBudget.smoke())
    result.save()        # experiments/search/<arch>__pareto.json

CLI: ``python -m repro.search.cli --arch tinyllama-1.1b --budget-smoke``.
"""
from .candidates import Candidate, FixedCandidate, SearchSpace
from .driver import SearchBudget, SearchResult, run_search
from .evaluate import (
    accuracy_proxy,
    backend_parity_report,
    describe_policy,
    energy_report,
    make_eval_batch,
    oracle_logits,
    policy_sweep,
    roundtrip_report,
)
from .inventory import (
    GemmEntry,
    energy_specs,
    layer_classes,
    model_inventory,
    quantizable_names,
)
from .pareto import ScoredCandidate, dominates, pareto_front

__all__ = [
    "Candidate", "FixedCandidate", "GemmEntry", "ScoredCandidate",
    "SearchBudget", "SearchResult", "SearchSpace", "accuracy_proxy",
    "backend_parity_report", "describe_policy", "dominates",
    "energy_report", "energy_specs", "layer_classes", "make_eval_batch",
    "model_inventory", "oracle_logits", "pareto_front", "policy_sweep",
    "quantizable_names", "roundtrip_report", "run_search",
]
