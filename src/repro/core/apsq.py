"""APSQ — Additive Partial Sum Quantization (paper §III, Algorithm 1).

Tile-based computation splits a GEMM's reduction dimension K into
``n_p = ceil(C_i / P_ci)`` partial-sum (PSUM) tiles (eq. 8).  A classical
IS/WS accelerator stores every additive PSUM ``AP_j`` (eq. 9) at INT32;
APSQ instead re-quantizes the *running accumulation* to INT8 (eq. 10):

    AP_i = Q_k^i(T_pi + alpha_{i-1} * AP_{i-1})

The grouping strategy (Algorithm 1) applies APSQ once per group of ``gs``
tiles and plain PSUM quantization (PSQ) to the other ``gs - 1`` tiles,
trading cascaded rounding error against PSUM buffer footprint.

This module provides:
  * ``apsq_accumulate_reference`` — a direct, unrolled transcription of
    Algorithm 1 (the oracle for tests and the Pallas kernel).
  * ``apsq_accumulate``           — lax.scan formulation (one step per full
    group) for large ``n_p`` so HLO size stays O(1) in n_p.
  * ``apsq_matmul``               — fused tiles-on-the-fly GEMM so the
    [n_p, ..., N] tile tensor is never materialized.
  * ``psq_accumulate``            — plain PSQ baseline (== gs >= n_p).

All outputs are *dequantized* (fake-quant floats on the INT grid); the
true-integer path lives in ``repro.kernels.apsq_matmul``.

Semantics of Algorithm 1 (indices 0-based, group starts S = {0, gs, 2gs, ...}):
  AP*_0 = Q_0(T_p0)
  group start i>0 : AP*_i = Q_i( sum_{j=i-gs}^{i-1} deq(AP*_j) + T_pi )
  tail j (< n_p-1): AP*_j = Q_j(T_pj)
  final tile n_p-1:
    if n_p-1 in S: T_o = deq(AP*_{n_p-1})                      (line 5)
    else:          T_o = deq(Q_{n_p-1}( sum_{l=i_last}^{n_p-2}
                                        deq(AP*_l) + T_p{n_p-1} ))  (line 14)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .quantizers import po2_quantize


def _fq(x, log2_alpha, bits):
    """PSUM fake quantizer: PO2-scale LSQ (paper forces PSUM scales to 2^k)."""
    return po2_quantize(x, log2_alpha, bits=bits, signed=True)


# ---------------------------------------------------------------------------
# Reference (unrolled Algorithm 1) — oracle for tests and the Pallas kernel.
# ---------------------------------------------------------------------------

def apsq_accumulate_reference(tiles, log2_alphas, gs: int, bits: int = 8):
    """Direct transcription of Algorithm 1.

    tiles:       [n_p, ...] PSUM tiles (floats; int products in deployment)
    log2_alphas: [n_p] learned log2 scales, one per quantizer Q_k^i
    gs:          group size (>= 1)
    Returns the dequantized output tile T_o with shape tiles.shape[1:].
    """
    n_p = tiles.shape[0]
    if gs < 1:
        raise ValueError(f"gs must be >= 1, got {gs}")
    stored = [None] * n_p  # dequantized stored INT8 PSUMs

    for i in range(0, n_p, gs):  # group starts
        prev = 0.0
        for j in range(max(0, i - gs), i):
            prev = prev + stored[j]
        stored[i] = _fq(prev + tiles[i], log2_alphas[i], bits)  # APSQ (line 5)
        if i == n_p - 1:
            return stored[i]
        for j in range(i + 1, min(i + gs, n_p)):
            if j < n_p - 1:
                stored[j] = _fq(tiles[j], log2_alphas[j], bits)  # PSQ (line 9)
            else:
                acc = tiles[j]
                for l in range(i, n_p - 1):
                    acc = acc + stored[l]
                return _fq(acc, log2_alphas[j], bits)  # final (line 14)
    raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# Scan formulation — O(1) HLO in n_p. One scan step per *full* group; the
# (possibly partial) last group is peeled off and handled exactly as the
# reference does.
# ---------------------------------------------------------------------------

def _group_step(carry, xs, *, gs, bits):
    """One full group: APSQ on the start tile, PSQ on the gs-1 tail tiles.

    carry: dequantized sum of the previous group's stored tiles.
    xs:    (tiles [gs, ...], log2_alphas [gs])
    """
    tiles, las = xs
    ap_start = _fq(carry + tiles[0], las[0], bits)
    if gs > 1:
        tails = jax.vmap(lambda t, la: _fq(t, la, bits))(tiles[1:], las[1:])
        new_carry = ap_start + jnp.sum(tails, axis=0)
    else:
        new_carry = ap_start
    return new_carry, ()


def apsq_accumulate(tiles, log2_alphas, gs: int, bits: int = 8):
    """Scan-based Algorithm 1; numerically identical to the reference."""
    n_p = tiles.shape[0]
    if gs < 1:
        raise ValueError(f"gs must be >= 1, got {gs}")
    n_groups = -(-n_p // gs)
    last_start = (n_groups - 1) * gs
    n_full = last_start // gs  # number of groups handled by the scan

    carry = jnp.zeros(tiles.shape[1:], tiles.dtype)
    if n_full > 0:
        xs = (
            tiles[: n_full * gs].reshape((n_full, gs) + tiles.shape[1:]),
            log2_alphas[: n_full * gs].reshape(n_full, gs),
        )
        carry, _ = jax.lax.scan(partial(_group_step, gs=gs, bits=bits), carry, xs)

    # Last group (indices last_start .. n_p-1), possibly partial.
    i = last_start
    ap_start = _fq(carry + tiles[i], log2_alphas[i], bits)
    if i == n_p - 1:
        return ap_start
    acc = ap_start
    for j in range(i + 1, n_p - 1):  # at most gs-2 unrolled PSQ tiles
        acc = acc + _fq(tiles[j], log2_alphas[j], bits)
    return _fq(acc + tiles[n_p - 1], log2_alphas[n_p - 1], bits)


def psq_accumulate(tiles, log2_alphas, bits: int = 8):
    """Plain PSUM quantization baseline: every tile quantized independently,
    summed once at the end (== Algorithm 1 with gs >= n_p)."""
    n_p = tiles.shape[0]
    return apsq_accumulate(tiles, log2_alphas, gs=n_p, bits=bits)


# ---------------------------------------------------------------------------
# Fused GEMM: PSUM tiles are produced on the fly inside the scan so the
# [n_p, ..., N] tile tensor never materializes (critical for QAT memory).
# ---------------------------------------------------------------------------

def _matmul_tile(xg, wg):
    """xg: [..., kt], wg: [kt, N] -> [..., N] partial sum."""
    return jax.lax.dot_general(
        xg, wg, (((xg.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _fused_group_step(carry, xs, *, gs, bits):
    xg, wg, las = xs  # xg: [gs, ..., kt], wg: [gs, kt, N], las: [gs]
    tiles = jax.vmap(_matmul_tile)(xg, wg)
    return _group_step(carry, (tiles, las), gs=gs, bits=bits)


def apsq_matmul(
    x: jax.Array,
    w: jax.Array,
    log2_alphas: jax.Array,
    *,
    n_p: int,
    gs: int,
    bits: int = 8,
) -> jax.Array:
    """GEMM ``x @ w`` with APSQ-quantized PSUM accumulation.

    x: [..., K] (already fake-quantized activations)
    w: [K, N]   (already fake-quantized weights)
    log2_alphas: [n_p] PSUM quantizer scales.
    K must be divisible by n_p (configs guarantee this; the paper's
    n_p = ceil(C_i/P_ci) with C_i a multiple of P_ci).
    """
    K = x.shape[-1]
    if K % n_p:
        raise ValueError(f"K={K} not divisible by n_p={n_p}")
    if log2_alphas.shape != (n_p,):
        raise ValueError(f"log2_alphas must be [n_p]={n_p}, got {log2_alphas.shape}")
    if n_p == 1:
        # Single PSUM tile: output quantization only (line 2 of Algorithm 1).
        return _fq(_matmul_tile(x, w), log2_alphas[0], bits)

    kt = K // n_p
    N = w.shape[-1]
    n_groups = -(-n_p // gs)
    last_start = (n_groups - 1) * gs
    n_full = last_start // gs

    xt = x.reshape(x.shape[:-1] + (n_p, kt))
    xt = jnp.moveaxis(xt, -2, 0)  # [n_p, ..., kt]
    wt = w.reshape(n_p, kt, N)

    carry = jnp.zeros(x.shape[:-1] + (N,), jnp.float32)
    if n_full > 0:
        xs = (
            xt[: n_full * gs].reshape((n_full, gs) + xt.shape[1:]),
            wt[: n_full * gs].reshape(n_full, gs, kt, N),
            log2_alphas[: n_full * gs].reshape(n_full, gs),
        )
        carry, _ = jax.lax.scan(
            partial(_fused_group_step, gs=gs, bits=bits), carry, xs
        )

    i = last_start
    ap_start = _fq(carry + _matmul_tile(xt[i], wt[i]), log2_alphas[i], bits)
    if i == n_p - 1:
        return ap_start
    acc = ap_start
    for j in range(i + 1, n_p - 1):
        acc = acc + _fq(_matmul_tile(xt[j], wt[j]), log2_alphas[j], bits)
    return _fq(acc + _matmul_tile(xt[n_p - 1], wt[n_p - 1]), log2_alphas[n_p - 1], bits)
