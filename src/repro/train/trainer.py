"""Trainer: sharded train_step factory + fault-tolerant host loop.

train_step composition (inside one jit):
  microbatch scan (gradient accumulation, fp32 accumulators)
    -> [optional] INT8-compressed cross-pod gradient psum (shard_map on the
       "pod" axis only; ICI-axis reductions stay in autodiff)
    -> global-norm clip -> AdamW (ZeRO-1 moment sharding optional)

Host loop (``Trainer.fit``):
  * restore latest checkpoint if present (reshard-on-load: the restore
    shardings come from the *current* mesh, so the same directory resumes
    on a different topology after node loss — elastic restart),
  * step-indexed deterministic data (replay-exact after restart),
  * async checkpoint every ``save_every`` + emergency save on SIGTERM,
  * straggler watchdog: wall-time per step vs a running median; slow steps
    are logged with their factor (the hook a cluster agent would consume).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.data import SyntheticCorpus, DataConfig
from repro.dist import (
    batch_spec,
    compress_tree_psum,
    optimizer_spec,
    shard_map,
    tree_specs,
)
from repro.models.config import ModelConfig
from repro.models.model import forward, init_lm, lm_loss, lm_specs
from repro.optim import OptimConfig, apply_updates, decay_mask, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    compress_dcn_grads: bool = False   # INT8 psum over "pod"
    zero1: bool = True                 # shard adam moments over "pod"
    save_every: int = 100
    log_every: int = 10
    straggler_factor: float = 2.0
    ckpt_dir: str = "/tmp/repro_ckpt"
    steps: int = 100


# ---------------------------------------------------------------------------
# Step factory
# ---------------------------------------------------------------------------

def _split_micro(batch: dict, n: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(f, batch)


def make_loss_fn(cfg: ModelConfig, mesh=None):
    def loss_fn(params, batch):
        logits = forward(
            params, cfg, batch["tokens"],
            embeds=batch.get("embeds"),
            enc_embeds=batch.get("enc_embeds"),
            mesh=mesh)
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:  # vlm: image prefix emits
            logits = logits[:, -labels.shape[1]:]  # logits for text only
        return lm_loss(logits, labels, batch.get("mask"), cfg.z_loss)
    return loss_fn


def make_grads_fn(cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
    """(params, batch) -> (loss, grads); microbatched, fp32 accumulation."""
    loss_fn = make_loss_fn(cfg, mesh)
    n = tcfg.microbatches

    def grads_fn(params, batch):
        if n == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        micro = _split_micro(batch, n)

        def body(acc, mb):
            loss_acc, g_acc = acc
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), ()

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(body, (jnp.zeros(()), g0), micro)
        inv = 1.0 / n
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    return grads_fn


def make_train_step(cfg: ModelConfig, ocfg: OptimConfig, tcfg: TrainConfig,
                    mesh=None):
    """Returns train_step(params, opt_state, batch) -> (p, s, metrics)."""
    grads_fn = make_grads_fn(cfg, tcfg, mesh)
    compress = (tcfg.compress_dcn_grads and mesh is not None
                and "pod" in mesh.axis_names and mesh.shape["pod"] > 1)

    def train_step(params, opt_state, batch):
        if compress:
            def local_grads(p, b):
                loss, g = grads_fn(p, b)
                g, _ = compress_tree_psum(g, "pod")
                return jax.lax.pmean(loss, "pod"), g

            bspec = jax.tree.map(lambda _: P("pod"), batch)
            loss, grads = shard_map(
                local_grads, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(), params), bspec),
                out_specs=(P(), jax.tree.map(lambda _: P(), params)),
                axis_names={"pod"},
            )(params, batch)
        else:
            loss, grads = grads_fn(params, batch)
        mask = decay_mask(params)
        params, opt_state, stats = apply_updates(params, grads, opt_state,
                                                 ocfg, mask)
        stats["loss"] = loss
        return params, opt_state, stats

    return train_step


def shardings_for_training(cfg: ModelConfig, ocfg: OptimConfig, mesh,
                           zero1: bool = True, rules=None):
    """(param, opt, batch-spec) shardings for jit in/out_shardings.

    Shapes come from ``jax.eval_shape`` — no allocation (dry-run safe).
    """
    p_shapes = jax.eval_shape(partial(init_lm, cfg=cfg),
                              jax.random.PRNGKey(0))
    specs = tree_specs(lm_specs(cfg), p_shapes, mesh, rules)
    p_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

    o_shapes = jax.eval_shape(partial(init_opt_state, cfg=ocfg), p_shapes)

    # m / v follow the param spec (+ ZeRO-1 pod axis); step is replicated.
    def moment_spec(tree_shapes, spec_tree):
        def f(sh, sp):
            if zero1:
                sp = optimizer_spec(sp, sh.shape, mesh)
            return NamedSharding(mesh, sp)
        return jax.tree.map(f, tree_shapes, spec_tree)

    def v_spec_tree(v_shapes):
        # adafactor factored dict leaves map to the param spec's prefix;
        # keep it simple: replicate factored stats (they are tiny).
        return jax.tree.map(
            lambda _: NamedSharding(mesh, P()), v_shapes)

    o_shardings = {
        "m": moment_spec(o_shapes["m"], specs),
        "v": (moment_spec(o_shapes["v"], specs)
              if not ocfg.adafactor_like else v_spec_tree(o_shapes["v"])),
        "step": NamedSharding(mesh, P()),
    }
    return p_shardings, o_shardings, p_shapes, o_shapes


# ---------------------------------------------------------------------------
# Host loop
# ---------------------------------------------------------------------------

class StragglerWatchdog:
    """Flags steps slower than ``factor`` x running median."""

    def __init__(self, factor: float = 2.0, window: int = 50):
        self.factor = factor
        self.times: list = []
        self.window = window
        self.flagged: list = []

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        slow = len(self.times) >= 5 and dt > self.factor * med
        if slow:
            self.flagged.append((step, dt, med))
        return slow


class Trainer:
    def __init__(self, cfg: ModelConfig, ocfg: OptimConfig,
                 tcfg: TrainConfig, mesh=None, rules=None):
        self.cfg, self.ocfg, self.tcfg = cfg, ocfg, tcfg
        self.mesh = mesh
        self.rules = rules
        self.watchdog = StragglerWatchdog(tcfg.straggler_factor)
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir)
        self.metrics_log: list = []

    def init_state(self, seed: int = 0):
        params = init_lm(jax.random.PRNGKey(seed), self.cfg)
        opt_state = init_opt_state(params, self.ocfg)
        return params, opt_state

    def fit(self, data_cfg: DataConfig | None = None, steps: int | None = None,
            params=None, opt_state=None, log=print):
        cfg, tcfg = self.cfg, self.tcfg
        steps = steps or tcfg.steps
        data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, seq_len=256, global_batch=8,
            frontend=cfg.frontend, d_model=cfg.d_model,
            n_frontend_tokens=cfg.n_frontend_tokens)
        corpus = SyntheticCorpus(data_cfg)

        start = 0
        if params is None:
            resume = latest_step(tcfg.ckpt_dir)
            if resume is not None:
                state, manifest = restore(tcfg.ckpt_dir)
                params, opt_state = state["params"], state["opt"]
                params = jax.tree.map(jnp.asarray, params)
                opt_state = jax.tree.map(jnp.asarray, opt_state)
                opt_state["step"] = jnp.asarray(opt_state["step"],
                                                jnp.int32).reshape(())
                start = int(manifest["step"])
                log(f"[trainer] resumed from step {start}")
            else:
                params, opt_state = self.init_state()

        step_fn = jax.jit(make_train_step(cfg, self.ocfg, tcfg, self.mesh))

        for step in range(start, steps):
            batch = jax.tree.map(jnp.asarray, corpus.batch_at(step))
            t0 = time.perf_counter()
            params, opt_state, stats = step_fn(params, opt_state, batch)
            stats = jax.tree.map(float, jax.device_get(stats))
            dt = time.perf_counter() - t0
            slow = self.watchdog.record(step, dt)
            self.metrics_log.append({**stats, "step": step, "dt": dt})
            if step % tcfg.log_every == 0 or slow:
                tag = " STRAGGLER" if slow else ""
                log(f"[trainer] step {step} loss {stats['loss']:.4f} "
                    f"gnorm {stats['grad_norm']:.3f} {dt*1e3:.0f}ms{tag}")
            if tcfg.save_every and (step + 1) % tcfg.save_every == 0:
                self.ckpt.save(step + 1,
                               {"params": params, "opt": opt_state})
        self.ckpt.wait()
        return params, opt_state
