"""Deterministic synthetic data pipeline with host sharding and prefetch.

Design goals at 1000+ nodes:
  * **Step-indexed determinism** — ``batch_at(step)`` is a pure function of
    (seed, step, host), so an elastic restart replays the exact token
    stream with no data-loader state in the checkpoint.
  * **Host sharding** — each host materializes only its slice of the global
    batch (``host_id / num_hosts``); the launcher assembles the global
    array via ``jax.make_array_from_process_local_data`` on real clusters
    and a plain reshape on single-host CPU.
  * **Background prefetch** — a double-buffered thread keeps the next batch
    ready so the input pipeline never blocks the step (straggler hygiene).

The corpus is a deterministic synthetic "language": a mixture of Zipfian
unigrams and copied motifs, so cross-entropy decreases meaningfully during
the example QAT runs (unlike uniform noise) while requiring no files.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 16
    n_motifs: int = 64
    frontend: str | None = None   # audio | vision -> also emit embeddings
    d_model: int = 0
    n_frontend_tokens: int = 0


class SyntheticCorpus:
    """Deterministic synthetic token stream (Zipf unigrams + motif copies)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Fixed motif bank; sequences interleave motifs with Zipf noise so
        # there is real predictable structure to learn.
        self.motifs = rng.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len), dtype=np.int32)

    def _zipf(self, rng, n):
        # Bounded Zipf via inverse-CDF on a truncated harmonic series.
        ranks = np.arange(1, self.cfg.vocab + 1, dtype=np.float64)
        # Cache the CDF (vocab can be 256k; compute once).
        if not hasattr(self, "_cdf"):
            w = ranks ** (-self.cfg.zipf_a)
            self._cdf = np.cumsum(w) / np.sum(w)
        u = rng.random(n)
        return np.searchsorted(self._cdf, u).astype(np.int32)

    def sequence(self, rng, length: int) -> np.ndarray:
        out = np.empty(length + 1, np.int32)
        i = 0
        while i <= length:
            if rng.random() < 0.5:  # motif copy
                m = self.motifs[rng.integers(self.cfg.n_motifs)]
                take = min(len(m), length + 1 - i)
                out[i:i + take] = m[:take]
                i += take
            else:
                take = min(int(rng.integers(8, 33)), length + 1 - i)
                out[i:i + take] = self._zipf(rng, take)
                i += take
        return out

    def batch_at(self, step: int, host_id: int = 0,
                 num_hosts: int = 1) -> dict:
        """Pure function of (seed, step, host): the host's batch slice."""
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        local_b = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        seqs = np.stack([self.sequence(rng, cfg.seq_len)
                         for _ in range(local_b)])
        batch = {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}
        if cfg.frontend == "vision":
            batch["embeds"] = rng.standard_normal(
                (local_b, cfg.n_frontend_tokens, cfg.d_model),
                dtype=np.float32)
        elif cfg.frontend == "audio":
            batch["enc_embeds"] = rng.standard_normal(
                (local_b, cfg.n_frontend_tokens or cfg.seq_len, cfg.d_model),
                dtype=np.float32)
        return batch


class PrefetchIterator:
    """Double-buffered background prefetch over ``corpus.batch_at``."""

    def __init__(self, corpus: SyntheticCorpus, start_step: int = 0,
                 host_id: int = 0, num_hosts: int = 1, depth: int = 2):
        self.corpus = corpus
        self.step = start_step
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.corpus.batch_at(step, self.host_id, self.num_hosts)
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()


def device_put_batch(batch: dict, shardings: dict | None = None) -> dict:
    """Host numpy batch -> device arrays (sharded when shardings given)."""
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    return {k: jax.device_put(v, shardings.get(k)) for k, v in batch.items()}
