"""Gate a fresh kernel_bench run against the checked-in throughput floor.

CI's kernel-backend job runs ``kernel_bench --smoke --json`` and then this
script with the floor extracted from the committed ``BENCH_kernel.json``
(``git show HEAD:BENCH_kernel.json``).  Backend records are matched on
(shape, m, k, n); each match must keep ``bit_equal`` true and hold
``pallas_gmacs_per_s`` at or above ``floor * slack``.  Interpret-mode
wall-clock on a shared CI box is noisy, so the default slack is generous —
the gate exists to catch order-of-magnitude launch-geometry regressions
(e.g. the 8x128 block cap this repo used to ship), not 10% jitter.

Exit codes: 0 pass, 1 regression, 2 usage/IO error.  No overlapping
records is a warning, not a failure (a floor from before a shape existed
cannot gate it).
"""
import argparse
import json
import sys


def _backend_records(payload: dict) -> dict:
    out = {}
    for rec in payload.get("records", []):
        if rec.get("section") != "backend":
            continue
        key = (rec.get("shape"), rec.get("m"), rec.get("k"), rec.get("n"))
        out[key] = rec
    return out


def check(new: dict, floor: dict, slack: float, print_fn=print) -> int:
    new_recs = _backend_records(new)
    floor_recs = _backend_records(floor)
    overlap = sorted(set(new_recs) & set(floor_recs))
    if not overlap:
        print_fn("floor,WARN,no overlapping backend records — nothing to "
                 "gate (floor predates these shapes?)")
        return 0
    failures = 0
    for key in overlap:
        shape, m, k, n = key
        rec, ref = new_recs[key], floor_recs[key]
        got = rec.get("pallas_gmacs_per_s", 0.0)
        need = ref.get("pallas_gmacs_per_s", 0.0) * slack
        equal = bool(rec.get("bit_equal", False))
        ok = equal and got >= need
        print_fn(f"floor,{'ok' if ok else 'FAIL'},{shape},m={m},k={k},n={n},"
                 f"pallas_gmacs_per_s={got} (floor*slack={need:.3f}),"
                 f"bit_equal={equal}")
        failures += 0 if ok else 1
    if failures:
        print_fn(f"floor,FAIL,{failures}/{len(overlap)} records below the "
                 f"checked-in throughput floor")
        return 1
    print_fn(f"floor,pass,{len(overlap)} records at or above floor")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new_json", help="fresh kernel_bench --json output")
    ap.add_argument("floor_json",
                    help="committed BENCH_kernel.json to gate against")
    ap.add_argument("--slack", type=float, default=0.25,
                    help="required fraction of the floor throughput "
                         "(default 0.25: flag >4x regressions, tolerate "
                         "shared-box timing noise)")
    args = ap.parse_args(argv)
    try:
        with open(args.new_json) as f:
            new = json.load(f)
        with open(args.floor_json) as f:
            floor = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"floor,ERROR,{e}")
        return 2
    return check(new, floor, args.slack)


if __name__ == "__main__":
    raise SystemExit(main())
