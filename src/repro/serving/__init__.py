"""Serving: continuous-batching engines (dense + paged INT8 KV cache)."""
from .engine import (
    PagedServingEngine,
    Request,
    ServingEngine,
    dequantize_kv,
    quantize_kv,
)
from .paged_cache import paged_cache_bytes
from .scheduler import PageAllocator, Scheduler

__all__ = [
    "PageAllocator", "PagedServingEngine", "Request", "Scheduler",
    "ServingEngine", "dequantize_kv", "paged_cache_bytes", "quantize_kv",
]
