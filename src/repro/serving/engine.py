"""Serving engine: prefill/decode split with batched requests.

Production pattern (vLLM-style, TPU-adapted):
  * fixed-shape request slots (``max_batch``) so every decode step hits the
    same compiled executable — no shape churn;
  * prefill pads prompts to ``prefill_chunk`` buckets (one compile per
    bucket, not per request) and installs caches/recurrent states into a
    free slot — new requests join between decode steps (continuous
    batching);
  * decode advances ALL active slots one token per call (per-slot position
    vector, vmapped over slots);
  * finished slots are freed and re-usable;
  * optional INT8 KV cache helpers (beyond-paper: APSQ-style PO2 scales
    applied to cache pages — ``quantize_kv``/``dequantize_kv``).

Integer serving (the calibrate -> export -> kernel-serving flow):

    params = calibrate_model(qat_params, cfg, batch)     # capture-based
    eng = ServingEngine.from_exported(params, cfg, backend="auto")
    eng.run([Request(uid=0, tokens=prompt)])

``from_exported`` exports every quantized linear to INT8 codes + PO2
shift exponents and the engine executes them through the ``repro.exec``
backend registry: ``backend="auto"`` (default) runs the real Pallas
APSQ kernel on TPU and the bit-identical jnp oracle elsewhere;
``backend="pallas"`` pins the kernel (interpret mode off-TPU — what CI
runs); ``backend="oracle"`` pins the reference semantics.  Greedy
decodes are token-for-token identical across backends.

The engine is host-driven (python around two jit'd functions) — the
launcher's ``serve.py`` runs it; the dry-run lowers ``serve_step`` from
``repro.launch.dryrun`` directly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import decode_step, init_decode_state


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray            # prompt
    max_new_tokens: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


# ---------------------------------------------------------------------------
# INT8 KV cache (beyond-paper, APSQ-style PO2 scales)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array):
    """Per-(batch, head) PO2-scale INT8 codes for KV cache pages.

    x: [B, S, H, hd].  Scales are powers of two so dequant is a shift —
    the same hardware argument the paper makes for PSUM scales (§II-B).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 3), keepdims=True)
    exp = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-8) / 127.0))
    scale = jnp.exp2(exp)
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def dequantize_kv(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (codes.astype(jnp.float32) * scale).astype(dtype)


def _batch_axes_tree(state, scan_layers: bool = True):
    """Per-leaf slot axis: stacked unit states are [n_units, B, ...] -> 1;
    unstacked / remainder states are [B, ...] -> 0."""
    def f(path, a):
        names = [str(getattr(p, "key", "")) for p in path]
        return 1 if (scan_layers and "units" in names) else 0
    return jax.tree_util.tree_map_with_path(f, state)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 cache_len: int = 1024, prefill_chunk: int = 64,
                 mesh=None, greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0, backend="auto"):
        from repro.exec import get_backend
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prefill_chunk = prefill_chunk
        self.mesh = mesh
        self.greedy = greedy
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        # Integer execution backend for deployed params (repro.exec):
        # "auto" (default) serves the Pallas kernel on TPU and the jnp
        # oracle elsewhere; "pallas"/"oracle" (or an ExecBackend instance,
        # e.g. PallasBackend(interpret=True)) pin one explicitly.  Float /
        # fake-quant params ignore it.
        self.backend = get_backend(backend)

        self.state = init_decode_state(cfg, max_batch, cache_len)
        self.pos = np.zeros(max_batch, np.int32)      # next position per slot
        self.slots: list = [None] * max_batch
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    @classmethod
    def from_exported(cls, params, cfg: ModelConfig, *, policy=None, **kw):
        """Serve the integer deployment path: export the calibrated QAT
        params (INT8 weight codes + PO2 shift exponents per layer, see
        ``repro.quant.export``) and run every projection GEMM through the
        ``kernels/apsq_matmul`` integer semantics inside decode.  The
        ``backend=`` knob picks the executor: ``auto`` (kernel on TPU,
        oracle elsewhere), ``pallas``, or ``oracle``."""
        from repro.quant.export import export_quantized
        deploy, _ = export_quantized(params, policy)
        return cls(deploy, cfg, **kw)

    # -- jitted bodies ------------------------------------------------------

    def _prefill_impl(self, params, state, tokens, slot, length):
        """Prefill one slot.  tokens: [1, Lpad] (bucket-padded); slot and
        length are traced scalars.  Steps the decode path token-by-token
        (identical cache layout to decode); state updates beyond ``length``
        are masked out so padding never pollutes recurrent state."""
        cfg = self.cfg
        fresh = init_decode_state(cfg, 1, self.cache_len)

        def body(carry, tok_pos):
            st, lg = carry
            tok, pos = tok_pos
            lg2, st2 = decode_step(params, cfg, st, tok[None, None], pos,
                                   mesh=self.mesh, backend=self.backend)
            valid = pos < length
            st = jax.tree.map(lambda a, b: jnp.where(valid, b, a), st, st2)
            lg = jnp.where(pos == length - 1, lg2[:, -1].astype(lg.dtype), lg)
            return (st, lg), ()

        lg0 = jnp.zeros((1, cfg.vocab), jnp.float32)
        (st, lg), _ = jax.lax.scan(
            body, (fresh, lg0),
            (tokens[0], jnp.arange(tokens.shape[1], dtype=jnp.int32)))
        axes = _batch_axes_tree(state, self.cfg.scan_layers)
        new_state = jax.tree.map(
            lambda full, s, ax: jax.lax.dynamic_update_slice_in_dim(
                full, s.astype(full.dtype), slot, axis=ax),
            state, st, axes)
        return new_state, lg

    def _decode_impl(self, params, state, tokens, pos, rng):
        """One decode step for all slots.  tokens [B, 1], pos [B]."""
        cfg = self.cfg
        axes = _batch_axes_tree(state, self.cfg.scan_layers)

        def one(st, tok, ps):
            # vmap strips the slot axis; reinsert a size-1 batch dim.
            st1 = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                               st, axes)
            lg, st2 = decode_step(params, cfg, st1, tok[None], ps,
                                  mesh=self.mesh, backend=self.backend)
            st2 = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax), st2, axes)
            return lg[0, -1], st2

        logits, new_state = jax.vmap(
            one, in_axes=(axes, 0, 0), out_axes=(0, axes))(state, tokens, pos)
        logits = logits / jnp.maximum(self.temperature, 1e-6)
        if self.greedy:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            nxt = jax.random.categorical(rng, logits, axis=-1)
        return nxt.astype(jnp.int32), new_state

    # -- host API -----------------------------------------------------------

    def add_request(self, req: Request) -> bool:
        """Prefill into a free slot; False if engine full."""
        try:
            slot = self.slots.index(None)
        except ValueError:
            return False
        L = int(len(req.tokens))
        pad = -L % self.prefill_chunk
        toks = np.pad(np.asarray(req.tokens, np.int32), (0, pad))[None]
        self.state, logits = self._prefill(
            self.params, self.state, jnp.asarray(toks),
            jnp.asarray(slot, jnp.int32), jnp.asarray(L, jnp.int32))
        self.slots[slot] = req
        self.pos[slot] = L
        req.out.append(int(jnp.argmax(logits[0])))
        return True

    def step(self) -> list:
        """One decode step for every active slot; returns finished requests."""
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return []
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].out[-1]
        self.rng, sub = jax.random.split(self.rng)
        nxt, self.state = self._decode(
            self.params, self.state, jnp.asarray(tokens),
            jnp.asarray(self.pos), sub)
        nxt = np.asarray(nxt)
        finished = []
        for i in active:
            r = self.slots[i]
            r.out.append(int(nxt[i]))
            self.pos[i] += 1
            if (len(r.out) >= r.max_new_tokens
                    or self.pos[i] >= self.cache_len - 1):
                r.done = True
                finished.append(r)
                self.slots[i] = None
        return finished

    def run(self, requests: list) -> list:
        """Continuous batching until every request completes."""
        pending = list(requests)
        done: list = []
        while pending or any(s is not None for s in self.slots):
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            done.extend(self.step())
        return done
