"""Optimizers: AdamW (+factored option), schedules, clipping, decay masks."""
from .adamw import (
    OptimConfig,
    apply_updates,
    decay_mask,
    global_norm,
    init_opt_state,
    lr_schedule,
)

__all__ = ["OptimConfig", "apply_updates", "decay_mask", "global_norm",
           "init_opt_state", "lr_schedule"]
