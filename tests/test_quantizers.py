"""Quantizer unit + property tests (LSQ, PO2, STE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    init_alpha_from,
    lsq_quantize,
    po2_quantize,
    po2_quantize_codes,
    po2_scale,
    qrange,
    round_ste,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def test_qrange():
    assert qrange(8, True) == (-128, 127)
    assert qrange(8, False) == (0, 255)
    assert qrange(4, True) == (-8, 7)


def test_round_ste_grad_is_identity():
    g = jax.grad(lambda x: jnp.sum(round_ste(x) * 2.0))(jnp.ones(4) * 0.3)
    np.testing.assert_allclose(g, 2.0 * np.ones(4))


@given(st.floats(0.01, 10.0), st.integers(2, 8))
def test_lsq_on_grid(alpha, bits):
    """Fake-quantized values land exactly on the alpha-spaced grid."""
    x = jnp.linspace(-20, 20, 101)
    y = lsq_quantize(x, jnp.asarray(alpha), bits=bits)
    codes = np.asarray(y) / alpha
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    qn, qp = qrange(bits, True)
    assert codes.min() >= qn - 1e-4 and codes.max() <= qp + 1e-4


@given(st.floats(0.05, 4.0))
def test_lsq_idempotent(alpha):
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))
    y1 = lsq_quantize(x, jnp.asarray(alpha))
    y2 = lsq_quantize(y1, jnp.asarray(alpha))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


@given(st.floats(-6.0, 6.0, allow_subnormal=False))
def test_po2_scale_is_power_of_two(la):
    s = float(po2_scale(jnp.asarray(la)))
    assert s == 2.0 ** np.floor(np.float32(la))


def test_po2_quantize_matches_codes_view():
    x = jax.random.normal(jax.random.PRNGKey(1), (32,)) * 10
    la = jnp.asarray(2.0)
    y = po2_quantize(x, la)
    codes, exp = po2_quantize_codes(x, la)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(codes, np.float32) * 2.0 ** float(exp),
        atol=1e-5)


def test_lsq_alpha_gradient_nonzero():
    x = jax.random.normal(jax.random.PRNGKey(2), (128,)) * 3
    g = jax.grad(lambda a: jnp.sum(jnp.square(lsq_quantize(x, a) - x)))(
        jnp.asarray(0.5))
    assert np.isfinite(float(g)) and abs(float(g)) > 0


def test_lsq_alpha_learns_toward_optimum():
    """A few SGD steps on alpha reduce quantization MSE (LSQ's premise)."""
    x = jax.random.normal(jax.random.PRNGKey(3), (512,))
    alpha = jnp.asarray(3.0)  # far too large
    mse = lambda a: jnp.mean(jnp.square(lsq_quantize(x, a) - x))
    m0 = float(mse(alpha))
    for _ in range(300):
        alpha = alpha - 1.0 * jax.grad(mse)(alpha)
    # LSQ's grad scale g = 1/sqrt(N*Qp) makes alpha adaptation deliberately
    # gentle; assert steady improvement, not convergence.
    assert float(mse(alpha)) < m0 * 0.85
    assert float(alpha) < 3.0  # moved toward the (smaller) optimum


def test_init_alpha_reasonable():
    x = jax.random.normal(jax.random.PRNGKey(4), (1000,))
    a = float(init_alpha_from(x, 8))
    assert 0.01 < a < 1.0
