#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps and run the test suite.
# Collection regressions (missing modules, import errors) fail the run
# because pytest errors out before running a single test.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt
python -m pip install --quiet "jax>=0.4.30" numpy 2>/dev/null || true

python -m pytest -x -q "$@"
