"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder device count before ANY jax import (jax locks the
device count at first init) — hence the first two lines.

For each cell the driver builds the jitted step (train_step for train
shapes, prefill/serve_step for inference shapes), lowers it with
ShapeDtypeStruct inputs (no allocation), compiles, and records:

  * memory_analysis()  — proves the program fits per-device HBM,
  * cost_analysis()    — FLOPs / bytes for the roofline (§Roofline),
  * collective bytes   — parsed from the optimized HLO,
  * the three roofline terms + dominant bottleneck.

Reports land in ``experiments/dryrun/<arch>__<cell>__<mesh>.json`` and are
aggregated into EXPERIMENTS.md by ``benchmarks/roofline_table.py``.
"""
import os

if "XLA_FLAGS" not in os.environ:  # first lines, before any jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import math
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, cells_for, get_config
from repro.dist import batch_spec, tree_specs
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig, ShapeCell
from repro.models.model import (
    decode_step,
    decode_state_specs,
    forward,
    init_decode_state,
    init_lm,
    lm_specs,
)
from repro.optim import OptimConfig, init_opt_state
from repro.roofline import (
    V5E,
    analyze_hlo,
    backend_corrected_terms,
    cost_terms,
    model_flops,
)
from repro.train import TrainConfig, make_train_step, shardings_for_training

SDS = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Per-arch tuning defaults (microbatching keeps train activations in HBM)
# ---------------------------------------------------------------------------

def default_microbatches(cfg: ModelConfig, cell: ShapeCell) -> int:
    if cell.kind != "train":
        return 1
    # Saved residual per unit ~ B*S*d*2 bytes / data shards; keep the
    # scan-carry footprint ~<2 GB/device across n_units.
    return 8 if cfg.d_model >= 2048 else 4


def active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: only top_k experts count)."""
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    total = sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))
    if cfg.mlp == "moe":
        expert = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts * cfg.n_layers
        active = expert * cfg.top_k // cfg.n_experts
        total = total - expert + active
    return int(total)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    B, S = cell.global_batch, cell.seq_len
    f32, i32 = jnp.float32, jnp.int32
    if cell.kind == "train":
        batch = {"tokens": SDS((B, S), i32), "labels": SDS((B, S), i32)}
        if cfg.frontend == "vision":
            batch["embeds"] = SDS((B, cfg.n_frontend_tokens, cfg.d_model),
                                  f32)
        if cfg.encdec:
            batch["enc_embeds"] = SDS((B, S, cfg.d_model), f32)
        return batch
    if cell.kind == "prefill":
        batch = {"tokens": SDS((B, S), i32)}
        if cfg.frontend == "vision":
            batch["embeds"] = SDS((B, cfg.n_frontend_tokens, cfg.d_model),
                                  f32)
        if cfg.encdec:
            batch["enc_embeds"] = SDS((B, S, cfg.d_model), f32)
        return batch
    # decode: one new token against a cache of S
    state_shapes = jax.eval_shape(
        partial(init_decode_state, cfg, B, S))
    batch = {
        "token": SDS((B, 1), i32),
        "pos": SDS((), i32),
        "state": state_shapes,
    }
    if cfg.encdec:
        batch["enc_out"] = SDS((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


# ---------------------------------------------------------------------------
# step builders (fn, arg specs, in/out shardings)
# ---------------------------------------------------------------------------

def _batch_shardings(specs: dict, mesh, batch: int):
    out = {}
    for k, v in specs.items():
        out[k] = NamedSharding(mesh, batch_spec(mesh, batch,
                                                extra_dims=len(v.shape) - 1))
    return out


def build_train(cfg: ModelConfig, cell: ShapeCell, mesh, *,
                microbatches: int | None = None, compress: bool = False,
                zero1: bool = True, remat_policy: str | None = None,
                rules=None):
    if remat_policy is not None:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    ocfg = OptimConfig()
    tcfg = TrainConfig(
        microbatches=microbatches or default_microbatches(cfg, cell),
        compress_dcn_grads=compress, zero1=zero1)
    step = make_train_step(cfg, ocfg, tcfg, mesh)
    p_sh, o_sh, p_shapes, o_shapes = shardings_for_training(
        cfg, ocfg, mesh, zero1=zero1, rules=rules)
    bspecs = input_specs(cfg, cell)
    b_sh = _batch_shardings(bspecs, mesh, cell.global_batch)
    args = (p_shapes, o_shapes, bspecs)
    in_sh = (p_sh, o_sh, b_sh)
    out_sh = (p_sh, o_sh, None)
    return step, args, in_sh, out_sh


def build_prefill(cfg: ModelConfig, cell: ShapeCell, mesh, rules=None):
    # params are an explicit input (sharded weights)
    def step(params, batch):
        logits = forward(params, cfg, batch["tokens"],
                         embeds=batch.get("embeds"),
                         enc_embeds=batch.get("enc_embeds"), mesh=mesh)
        return logits[:, -1:, :]

    p_shapes = jax.eval_shape(lambda k: init_lm(k, cfg),
                              jax.random.PRNGKey(0))
    p_specs = tree_specs(lm_specs(cfg), p_shapes, mesh, rules)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    bspecs = input_specs(cfg, cell)
    b_sh = _batch_shardings(bspecs, mesh, cell.global_batch)
    return step, (p_shapes, bspecs), (p_sh, b_sh), None


def build_decode(cfg: ModelConfig, cell: ShapeCell, mesh, rules=None):
    def step(params, batch):
        logits, new_state = decode_step(
            params, cfg, batch["state"], batch["token"], batch["pos"],
            enc_out=batch.get("enc_out"), mesh=mesh)
        return logits, new_state

    p_shapes = jax.eval_shape(lambda k: init_lm(k, cfg),
                              jax.random.PRNGKey(0))
    p_specs = tree_specs(lm_specs(cfg), p_shapes, mesh, rules)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)

    bspecs = input_specs(cfg, cell)
    st_specs = tree_specs(decode_state_specs(cfg), bspecs["state"], mesh,
                          rules)
    st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), st_specs)
    b_sh = {
        "token": NamedSharding(mesh, batch_spec(mesh, cell.global_batch, 1)),
        "pos": NamedSharding(mesh, P()),
        "state": st_sh,
    }
    if "enc_out" in bspecs:
        b_sh["enc_out"] = NamedSharding(
            mesh, batch_spec(mesh, cell.global_batch, 2))
    return step, (p_shapes, bspecs), (p_sh, b_sh), (None, st_sh)


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh, rules=None, **kw):
    if cell.kind == "train":
        return build_train(cfg, cell, mesh, rules=rules, **kw)
    if cell.kind == "prefill":
        return build_prefill(cfg, cell, mesh, rules=rules)
    return build_decode(cfg, cell, mesh, rules=rules)


# ---------------------------------------------------------------------------
# Quant-policy sweeps + execution-backend parity — shared with the policy
# search (``repro.search``); re-exported here so existing callers keep
# importing them from the dry-run module.
# ---------------------------------------------------------------------------

from repro.search.evaluate import (  # noqa: E402  (re-export)
    backend_parity_report,
    describe_policy,
    policy_sweep,
)


# ---------------------------------------------------------------------------
# Lower + compile + analyze one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, cell_name: str, *, multi_pod: bool = False,
             quant="none", verbose: bool = True,
             overrides: dict | None = None, tag: str = "",
             rules=None, backend_parity: bool = False,
             quant_name: str | None = None, **kw) -> dict:
    """Lower + compile one cell.  ``quant`` is a preset string, an explicit
    ``QuantConfig``, or a per-layer ``QuantPolicy`` (heterogeneous policies
    from ``repro.quant.policy_presets`` — the ``--quant-policy`` sweep);
    ``backend_parity`` attaches an oracle-vs-pallas execution check for
    the arch's deployed GEMM shape to the report."""
    cfg = get_config(arch, quant=quant)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = cells_for(arch)[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    quant_label = quant_name or (
        quant if isinstance(quant, str) else type(quant).__name__)
    report = {"arch": arch, "cell": cell_name, "mesh": mesh_name,
              "quant": quant_label, "tag": tag, "ok": False,
              "overrides": {k: str(v) for k, v in (overrides or {}).items()}}
    if not isinstance(quant, str):
        report["quant_policy"] = describe_policy(quant)
    if backend_parity:
        report["backend_parity"] = backend_parity_report(cfg)
    t0 = time.time()
    try:
        step, args, in_sh, out_sh = build_cell(cfg, cell, mesh, rules=rules,
                                               **kw)
        with mesh:
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        report["compile_s"] = round(time.time() - t0, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    report[k] = int(v)
        # Loop-aware HLO cost (cost_analysis() counts while bodies once).
        hlo = analyze_hlo(compiled.as_text())
        terms = cost_terms(
            {"flops": hlo["flops"], "bytes accessed": hlo["bytes"]},
            hlo["collectives"], n_chips)
        report.update(terms)
        # Backend-aware roofline (ROADMAP follow-up): when this cell
        # carries a measured backend_parity timing, scale the analytic
        # compute term by measured/analytic on the probe GEMM instead of
        # trusting datasheet rates alone.
        if report.get("backend_parity"):
            corr = backend_corrected_terms(terms, report["backend_parity"])
            if corr:
                report["backend_roofline"] = corr
        report["collectives"] = hlo["collectives"]
        report["collective_counts"] = hlo["collective_counts"]
        report["hlo_warnings"] = hlo["warnings"][:10]
        xla_cost = compiled.cost_analysis()
        xla_cost = (xla_cost[0] if isinstance(xla_cost, (list, tuple))
                    else xla_cost) or {}
        report["xla_flops_unscaled"] = float(xla_cost.get("flops", 0.0))

        n_act = active_params(cfg)
        tokens = (cell.global_batch * cell.seq_len
                  if cell.kind in ("train", "prefill")
                  else cell.global_batch)
        mf = model_flops(n_act, tokens, training=(cell.kind == "train"))
        report["model_flops_global"] = mf
        report["model_flops_per_chip"] = mf / n_chips
        if terms["flops"]:
            report["useful_flops_fraction"] = (
                mf / n_chips / terms["flops"])
        report["ok"] = True
    except Exception as e:  # noqa: BLE001 — report every failure mode
        report["error"] = f"{type(e).__name__}: {e}"
        report["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        status = "OK " if report["ok"] else "FAIL"
        extra = (f"dom={report.get('dominant', '?'):>12s} "
                 f"comp={report.get('compute_s', 0):.3e}s "
                 f"mem={report.get('memory_s', 0):.3e}s "
                 f"coll={report.get('collective_s', 0):.3e}s"
                 if report["ok"] else report.get("error", ""))
        print(f"[dryrun] {status} {arch:24s} {cell_name:12s} "
              f"{mesh_name:8s} {report.get('compile_s', 0):6.1f}s  {extra}",
              flush=True)
    return report


def save_report(report: dict, out_dir: str = "experiments/dryrun"):
    os.makedirs(out_dir, exist_ok=True)
    tag = report.get("tag") or ""
    name = (f"{report['arch']}__{report['cell']}__{report['mesh']}"
            f"__{report.get('quant', 'none')}"
            + (f"__{tag}" if tag else "") + ".json")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump({k: v for k, v in report.items() if k != "traceback"},
                  f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--cell", default="all",
                    help="shape cell or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--quant", default="none",
                    choices=("none", "w8a8", "psq", "apsq"))
    ap.add_argument("--quant-policy", default=None,
                    help="named heterogeneous per-layer policy "
                         "(repro.quant.policy_presets; overrides --quant) "
                         "or 'all' to sweep every preset")
    ap.add_argument("--backend-parity", action="store_true",
                    help="attach an oracle-vs-pallas execute_gemm parity "
                         "+ timing check to each quantized cell report")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--compress", action="store_true",
                    help="INT8 DCN gradient compression (multi-pod train)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    quants = [(args.quant, args.quant)]
    if args.quant_policy is not None:
        try:
            quants = policy_sweep(args.quant_policy)
        except KeyError as e:
            raise SystemExit(e.args[0])

    archs = ARCH_NAMES if args.arch == "all" else (args.arch,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]
    failures = 0
    for arch in archs:
        cell_names = (cells_for(arch) if args.cell == "all"
                      else (args.cell,))
        for cell_name in cell_names:
            if cell_name not in cells_for(arch):
                print(f"[dryrun] SKIP {arch} {cell_name} (inapplicable)")
                continue
            for mp in meshes:
                for qname, quant in quants:
                    kw = {}
                    if cell_name.startswith("train"):
                        kw = {"microbatches": args.microbatches,
                              "compress": args.compress}
                    rep = run_cell(arch, cell_name, multi_pod=mp,
                                   quant=quant, quant_name=qname,
                                   backend_parity=args.backend_parity,
                                   **kw)
                    save_report(rep, args.out)
                    failures += 0 if rep["ok"] else 1
    print(f"[dryrun] done; {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
