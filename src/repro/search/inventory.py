"""GEMM inventory: one layer namespace shared by quant and energy.

The model zoo assigns every quantized linear a stable name
(``unit.0.mix.wq``, ``rem.1.ffn.wo``, ``encoder.unit.0.xattn.wk``,
``head`` — see ``models.model.init_layer``); ``QuantPolicy`` rules match
those names.  The analytical energy model, meanwhile, consumes anonymous
``LayerShape`` walks (``energy.workloads``).  This module closes the gap:
``model_inventory(cfg, seq_len)`` walks a ``ModelConfig`` exactly as
``init_lm`` does — dense / attention / MoE / RWKV / RG-LRU blocks,
scan-stacked units, remainder layers, the encoder stack, the tied head —
and emits one ``GemmEntry`` per GEMM whose ``shape.name`` IS the quant
layer name.  A policy therefore resolves against the inventory with the
same ``fnmatch`` rules that drive parameter init, and the energy model
scores the exact GEMMs the JAX forward executes.

Non-policy GEMMs (attention score/value GEMMs, the MoE router, gates,
the untied head) carry ``policy_name=None``: they contribute energy at
the INT32-PSUM baseline but are outside the quantizer namespace.
"""
from __future__ import annotations

import dataclasses

from repro.core import effective_n_p
from repro.energy.model import LayerEnergySpec, LayerShape
from repro.models.config import ModelConfig
from repro.quant.policy import resolve_quant


@dataclasses.dataclass(frozen=True)
class GemmEntry:
    """One GEMM of a model: its energy shape + quant-namespace identity.

    ``shape.name`` equals ``policy_name`` for quantizable projections so
    the two subsystems literally share one namespace; score GEMMs and
    other unquantized projections keep a descriptive name with
    ``policy_name=None``.
    """

    shape: LayerShape
    policy_name: str | None = None

    @property
    def quantizable(self) -> bool:
        return self.policy_name is not None


def _layer_entries(cfg: ModelConfig, kind: str, name: str, T: int, Tkv: int,
                   repeat: int, *, cross: bool = False) -> list:
    """GEMMs of one block named ``{name}.mix.* / {name}.ffn.*``.

    ``repeat`` folds identical layers (scan-stacked units share quantizer
    state and names per pattern position, exactly as ``init_unit`` names
    them), so the inventory stays O(pattern), not O(n_layers).
    """
    d, hd = cfg.d_model, cfg.hd
    out: list = []

    def q(n: str, tokens: int, c_i: int, c_o: int, rep: int = 1):
        out.append(GemmEntry(LayerShape(n, tokens, c_i, c_o,
                                        repeat=rep * repeat), n))

    def anon(n: str, tokens: int, c_i: int, c_o: int, rep: int = 1):
        out.append(GemmEntry(LayerShape(n, tokens, c_i, c_o,
                                        repeat=rep * repeat), None))

    if kind in ("attn", "local"):
        q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
        kv_t = Tkv if kind == "attn" else min(cfg.local_window, Tkv)
        q(f"{name}.mix.wq", T, d, q_dim)
        q(f"{name}.mix.wk", T, d, kv_dim)
        q(f"{name}.mix.wv", T, d, kv_dim)
        q(f"{name}.mix.wo", T, q_dim, d)
        anon(f"{name}.mix.scores", T, hd, kv_t, rep=cfg.n_heads)
        anon(f"{name}.mix.values", T, kv_t, hd, rep=cfg.n_heads)
    elif kind == "rwkv":
        a = cfg.n_heads * hd
        for w in ("wr", "wk", "wv", "wg"):
            q(f"{name}.mix.{w}", T, d, a)
        q(f"{name}.mix.wo", T, a, d)
    elif kind == "rglru":
        r = cfg.d_rnn
        q(f"{name}.mix.wx", T, d, r)
        q(f"{name}.mix.wy", T, d, r)
        q(f"{name}.mix.wo", T, r, d)
        anon(f"{name}.mix.gates", T, r, 2 * r)
    if cross:
        q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
        q(f"{name}.xattn.wq", T, d, q_dim)
        q(f"{name}.xattn.wk", Tkv, d, kv_dim)
        q(f"{name}.xattn.wv", Tkv, d, kv_dim)
        q(f"{name}.xattn.wo", T, q_dim, d)
        anon(f"{name}.xattn.scores", T, hd, Tkv, rep=cfg.n_heads)
        anon(f"{name}.xattn.values", T, Tkv, hd, rep=cfg.n_heads)
    # channel mix
    if cfg.mlp == "moe":
        anon(f"{name}.ffn.router", T, d, cfg.n_experts)
        q(f"{name}.ffn.wi", T, d, cfg.d_ff, rep=cfg.top_k)
        q(f"{name}.ffn.wg", T, d, cfg.d_ff, rep=cfg.top_k)
        q(f"{name}.ffn.wo", T, cfg.d_ff, d, rep=cfg.top_k)
    elif cfg.mlp == "rwkv_cm":
        anon(f"{name}.ffn.wr", T, d, d)
        q(f"{name}.ffn.wk", T, d, cfg.d_ff)
        q(f"{name}.ffn.wv", T, cfg.d_ff, d)
    elif cfg.mlp == "swiglu":
        q(f"{name}.ffn.wi", T, d, cfg.d_ff)
        q(f"{name}.ffn.wg", T, d, cfg.d_ff)
        q(f"{name}.ffn.wo", T, cfg.d_ff, d)
    else:  # gelu
        q(f"{name}.ffn.wi", T, d, cfg.d_ff)
        q(f"{name}.ffn.wo", T, cfg.d_ff, d)
    return out


def _unit_entries(cfg: ModelConfig, prefix: str, T: int, Tkv: int,
                  repeat: int, *, cross: bool = False) -> list:
    out: list = []
    for i, kind in enumerate(cfg.block_pattern):
        out += _layer_entries(cfg, kind, f"{prefix}.{i}", T, Tkv, repeat,
                              cross=cross)
    return out


def model_inventory(cfg: ModelConfig, seq_len: int,
                    stage: str = "prefill") -> list:
    """Named ``GemmEntry`` walk of everything ``init_lm(cfg)`` builds.

    stage='prefill': full-sequence pass (T = seq_len).
    stage='decode' : one token against a seq_len KV history (T = 1).
    """
    if stage not in ("prefill", "decode"):
        raise ValueError(f"stage must be prefill|decode, got {stage!r}")
    T = 1 if stage == "decode" else seq_len
    entries: list = []
    if cfg.encdec and cfg.n_enc_layers:
        n_enc_units = cfg.n_enc_layers // len(cfg.block_pattern)
        entries += _unit_entries(cfg, "encoder.unit", seq_len, seq_len,
                                 n_enc_units)
    entries += _unit_entries(cfg, "unit", T, seq_len, cfg.n_units,
                             cross=cfg.encdec)
    for i in range(cfg.n_rem):
        entries += _layer_entries(cfg, cfg.block_pattern[i], f"rem.{i}",
                                  T, seq_len, 1, cross=cfg.encdec)
    # Head: the tied-embedding logits GEMM is in the quant namespace
    # ("head", calibrated by calibrate_model); the untied head is a plain
    # float projection.
    head = GemmEntry(LayerShape("head", T, cfg.d_model, cfg.vocab),
                     "head" if cfg.tie_embeddings else None)
    entries.append(head)
    return entries


def quantizable_names(inventory: list) -> list:
    """Stable layer names a policy can address, in walk order."""
    return [e.policy_name for e in inventory if e.quantizable]


def layer_classes(inventory: list) -> dict:
    """Group quantizable names into the glob classes candidates tune.

    Returns ``{glob_pattern: [names]}`` for the classes present in this
    architecture — the knobs of the (gs, n_p) search space.  Order matters
    (first match wins in ``QuantPolicy``): more specific classes first.
    """
    classes = (
        ("encoder.*", lambda n: n.startswith("encoder.")),
        ("rem.*", lambda n: n.startswith("rem.")),
        ("*.xattn.*", lambda n: ".xattn." in n),
        ("*.mix.*", lambda n: ".mix." in n),
        ("*.ffn.*", lambda n: ".ffn." in n),
        ("head", lambda n: n == "head"),
    )
    # Dict order == the classes-tuple order (NOT inventory walk order):
    # callers turn this straight into QuantPolicy rules, where the first
    # match wins — a generic '*.mix.*' rule listed before 'rem.*' would
    # silently shadow the remainder-layer knob.
    out: dict = {pattern: [] for pattern, _ in classes}
    for name in quantizable_names(inventory):
        for pattern, match in classes:
            if match(name):
                out[pattern].append(name)
                break
    return {p: names for p, names in out.items() if names}


def energy_specs(inventory: list, policy, acc) -> list:
    """Resolve a ``QuantPolicy`` against the inventory -> LayerEnergySpec.

    Quantized layers with PSUM handling run at ``psum.bits`` with their
    policy's ``gs`` (PSQ keeps every tile live: gs = n_p); W8A8-only and
    unquantized layers accumulate at the INT32 baseline.  The energy-side
    tile count is ``max(ceil(C_i / P_ci), policy n_p)``: the MAC array's
    physical input-channel parallelism floors how coarsely K can be tiled
    (a quantizer spanning several hardware tiles still pays every
    buffer read-modify-write), while a policy tiling K *finer* than the
    array genuinely adds PSUM traffic.  The policy's n_p is first clamped
    to a divisor of C_i exactly as ``quant_params_init`` clamps it.
    ``policy`` may be None (the all-float model).
    """
    specs: list = []
    for e in inventory:
        resolved = (resolve_quant(policy, e.policy_name)
                    if e.quantizable else None)
        if resolved is None or resolved.psum.mode == "none":
            specs.append(LayerEnergySpec(e.shape))
            continue
        n_hw = -(-e.shape.c_i // acc.P_ci)
        n_p = max(n_hw, effective_n_p(e.shape.c_i, resolved.psum.n_p))
        gs = n_p if resolved.psum.mode == "psq" else min(resolved.psum.gs,
                                                         n_p)
        specs.append(LayerEnergySpec(e.shape, psum_bits=resolved.psum.bits,
                                     gs=gs, n_p=n_p))
    return specs
