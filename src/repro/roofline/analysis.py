"""Three-term roofline from a compiled dry-run artifact (TPU v5e target).

  compute term    = HLO_FLOPs   / (chips * peak_FLOP/s)
  memory term     = HLO_bytes   / (chips * HBM_bw)
  collective term = coll_bytes  / (chips * link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs / bytes accessed;
collective bytes are NOT in cost_analysis, so ``collective_bytes`` parses
the optimized HLO text and sums operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants (v5e): 197 TFLOP/s bf16 per chip; 819 GB/s HBM;
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    link_bw: float = 50e9           # bytes/s per ICI link
    dcn_bw: float = 25e9            # bytes/s per host crossing pods


V5E = HwSpec()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# one shape token: dtype[d0,d1,...] with optional layout {...}
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+[a-z0-9]*|pred)\[([0-9,]*)\]")
_TUPLE_SPLIT_RE = re.compile(r"\)\s*,")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from optimized HLO text.

    For every instruction line ``%x = <shape> <op>(<operands>)``, operand
    shapes appear inline; we sum them (falls back to the result shape when
    no inline operand shapes are printed).
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(COLLECTIVE_OPS)
                      + r")(\.[0-9]+)?\(", s)
        if not m:
            continue
        kind = m.group(2)
        # operands: text inside the outermost call parens
        call = s[m.end() - 1:]
        depth = 0
        end = 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = call[1:end]
        op_shapes = _SHAPE_RE.findall(operands)
        if op_shapes:
            b = sum(_shape_bytes(dt, dims) for dt, dims in op_shapes)
        else:
            res_shapes = _SHAPE_RE.findall(m.group(1))
            b = sum(_shape_bytes(dt, dims) for dt, dims in res_shapes)
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    out["counts"] = counts
    return out


def cost_terms(cost: dict, coll: dict, n_chips: int,
               hw: HwSpec = V5E, dcn_bytes: int = 0) -> dict:
    """The three roofline terms, in seconds.

    ``cost`` is ``compiled.cost_analysis()`` (flops / bytes accessed are
    whole-program totals across the SPMD program = per-chip numbers after
    partitioning; XLA reports the per-replica program).
    """
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll_b = float(coll.get("total", 0))
    t_compute = flops / hw.peak_flops
    t_memory = bytes_accessed / hw.hbm_bw
    t_coll = coll_b / hw.link_bw
    t_dcn = dcn_bytes / hw.dcn_bw if dcn_bytes else 0.0
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll, "dcn_s": t_dcn}
    dominant = max(terms, key=lambda k: terms[k])
    bound = max(t_compute, t_memory, t_coll, t_dcn)
    total = t_compute + t_memory + t_coll + t_dcn
    return {
        **terms,
        "dominant": dominant,
        "bound_s": bound,
        "roofline_fraction": (t_compute / bound) if bound > 0 else 0.0,
        "flops": flops,
        "bytes": bytes_accessed,
        "collective_bytes": coll_b,
        "n_chips": n_chips,
    }


def model_flops(n_params_active: int, n_tokens: int,
                training: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference."""
    per_tok = 6 if training else 2
    return float(per_tok) * n_params_active * n_tokens


def useful_fraction(mf: float, hlo_flops: float) -> float:
    """MODEL_FLOPS / HLO_FLOPs — catches remat / redundancy waste.

    HLO flops here are per-chip; ``mf`` must be per-chip too (divide the
    global model FLOPs by n_chips before calling).
    """
    return mf / hlo_flops if hlo_flops else 0.0


# ---------------------------------------------------------------------------
# Backend-aware correction: measured kernel timings vs the analytic model
# ---------------------------------------------------------------------------

def gemm_analytic_us(m: int, k: int, n: int, hw: HwSpec = V5E) -> float:
    """Analytic roofline time (us) of one INT8 GEMM [m,k]x[k,n].

    INT8 operands in, INT32 PSUM result out — the deployed shape the
    ``backend_parity`` probe measures.
    """
    flops = 2.0 * m * k * n
    bytes_ = m * k + k * n + 4.0 * m * n
    return max(flops / hw.peak_flops, bytes_ / hw.hbm_bw) * 1e6


def backend_corrected_terms(terms: dict, parity: dict,
                            hw: HwSpec = V5E) -> dict:
    """Fold a measured ``backend_parity`` timing into the roofline.

    The dry-run cost model is analytic (GEMM FLOPs/bytes at datasheet
    rates); the parity probe *measures* the same deployed GEMM through
    the execution backend.  ``correction = measured / analytic`` on the
    probe shape scales the compute term — so quantized cells report what
    the kernel actually delivers, not what the datasheet promises.  Off
    TPU the kernel runs in interpret mode and the factor is enormous;
    it becomes meaningful on hardware (the measurement path is the same).
    Returns {} when the parity report has no usable timing.
    """
    shape = parity.get("shape")
    measured = parity.get("pallas_us", parity.get("oracle_us"))
    if not shape or not measured:
        return {}
    analytic = gemm_analytic_us(*shape, hw=hw)
    correction = measured / analytic if analytic else 0.0
    corrected_compute = terms.get("compute_s", 0.0) * correction
    corrected_bound = max(corrected_compute, terms.get("memory_s", 0.0),
                          terms.get("collective_s", 0.0),
                          terms.get("dcn_s", 0.0))
    return {
        "probe_shape": list(shape),
        "probe_measured_us": round(measured, 1),
        "probe_analytic_us": analytic,
        "correction": correction,
        "corrected_compute_s": corrected_compute,
        "corrected_bound_s": corrected_bound,
    }
