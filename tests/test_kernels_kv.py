"""INT8 KV-cache decode attention kernel vs oracle + fp32 tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.int8_kv_attention import (
    cache_bytes,
    fp_attention_ref,
    int8_kv_attention,
    int8_kv_attention_f32,
    int8_kv_attention_ref,
    quantize_kv_po2,
)

KEY = jax.random.PRNGKey(0)


def _case(B, S, Hq, Hkv, hd, length=None, seed=0):
    k0 = jax.random.fold_in(KEY, seed)
    q = jax.random.normal(k0, (B, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (B, S, Hkv, hd))
    length = jnp.full((B,), length if length is not None else S, jnp.int32)
    return q, k, v, length


@pytest.mark.parametrize("B,S,Hq,Hkv,hd,block_s", [
    (2, 64, 4, 2, 16, 32),
    (1, 128, 8, 1, 32, 128),   # MQA
    (2, 96, 4, 4, 16, 32),     # MHA
    (1, 64, 6, 2, 8, 16),
])
def test_kernel_matches_oracle(B, S, Hq, Hkv, hd, block_s):
    q, k, v, length = _case(B, S, Hq, Hkv, hd)
    kc, ke = quantize_kv_po2(k)
    vc, ve = quantize_kv_po2(v)
    ref = int8_kv_attention_ref(q, kc, vc, ke, ve, length)
    out = int8_kv_attention(q, kc, vc, ke, ve, length, block_s=block_s,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_partial_cache_length_masked():
    q, k, v, _ = _case(2, 64, 4, 2, 16, seed=3)
    kc, ke = quantize_kv_po2(k)
    vc, ve = quantize_kv_po2(v)
    L = jnp.asarray([17, 40], jnp.int32)
    ref = int8_kv_attention_ref(q, kc, vc, ke, ve, L)
    out = int8_kv_attention(q, kc, vc, ke, ve, L, block_s=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)
    # junk beyond L must not leak: perturb the masked region, same output
    kc2 = kc.at[:, 50:].set(127)
    out2 = int8_kv_attention(q, kc2, vc, ke, ve, L, block_s=32,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=1e-6, atol=1e-7)


def test_int8_path_close_to_fp32():
    q, k, v, length = _case(2, 128, 8, 2, 32, seed=5)
    fp = fp_attention_ref(q, k, v, length)
    out = int8_kv_attention_f32(q, k, v, length, block_s=64,
                                interpret=True)
    rel = float(jnp.mean(jnp.abs(out - fp)) / jnp.mean(jnp.abs(fp)))
    assert rel < 0.03, rel  # ~8-bit cache quantization noise


def test_quantize_roundtrip_po2():
    x = jax.random.normal(KEY, (2, 32, 4, 16)) * 3
    codes, exp = quantize_kv_po2(x)
    assert codes.dtype == jnp.int8 and exp.shape == (2, 4)
    from repro.kernels.int8_kv_attention import dequantize_kv_po2
    back = dequantize_kv_po2(codes, exp)
    rel = float(jnp.mean(jnp.abs(back - x)) / jnp.mean(jnp.abs(x)))
    assert rel < 0.02  # PO2 scales are up to 2x coarser than optimal
    # scales are powers of two (shift-dequant in hardware)
    s = np.exp2(np.asarray(exp, np.float64))
    assert np.all(np.log2(s) == np.round(np.log2(s)))


def test_cache_bytes_halved():
    b = cache_bytes(8, 32768, 4, 128)
    assert b["int8"] < b["bf16"] * 0.51


@pytest.mark.parametrize("length", [32,   # exactly one block
                                    33,   # length % block_s == 1
                                    1,    # first position only
                                    64])  # every block full
def test_block_s_boundary_lengths(length):
    """Valid-length mask at block edges: the online-softmax carry must
    neither drop the last valid position nor admit the first masked one."""
    q, k, v, _ = _case(2, 64, 4, 2, 16, seed=7)
    kc, ke = quantize_kv_po2(k)
    vc, ve = quantize_kv_po2(v)
    L = jnp.full((2,), length, jnp.int32)
    ref = int8_kv_attention_ref(q, kc, vc, ke, ve, L)
    out = int8_kv_attention(q, kc, vc, ke, ve, L, block_s=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_ragged_lengths_across_batch():
    """Every batch row at a different fill level (the continuous-batching
    shape: slots admitted at different times), including block boundaries."""
    q, k, v, _ = _case(4, 96, 4, 2, 16, seed=8)
    kc, ke = quantize_kv_po2(k)
    vc, ve = quantize_kv_po2(v)
    L = jnp.asarray([1, 32, 33, 96], jnp.int32)
    ref = int8_kv_attention_ref(q, kc, vc, ke, ve, L)
    out = int8_kv_attention(q, kc, vc, ke, ve, L, block_s=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)
    # rows are independent: rerunning row 0 alone reproduces its output
    solo = int8_kv_attention(q[:1], kc[:1], vc[:1], ke[:1], ve[:1],
                             L[:1], block_s=32, interpret=True)
    np.testing.assert_allclose(np.asarray(solo[0]), np.asarray(out[0]),
                               rtol=1e-6, atol=1e-7)
