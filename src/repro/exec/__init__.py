"""Execution-backend layer: how the integer op families are computed.

One registry (``oracle`` | ``pallas`` | ``auto``) behind two entry points:
``execute_gemm(deployed_layer, x)`` for deployed integer GEMMs and
``execute_kv_attention(q, k_codes, v_codes, ...)`` for decode attention
over an INT8 KV cache — see ``backends.py`` for the design.
"""
from .backends import (
    AutoBackend,
    DEFAULT_BACKEND,
    ExecBackend,
    OracleBackend,
    PallasBackend,
    ShardedBackend,
    available_backends,
    backend_parity_check,
    execute_expert_gemm,
    execute_gemm,
    execute_kv_attention,
    get_backend,
    kv_block_size,
    quantize_activations,
    register_backend,
)

__all__ = [
    "AutoBackend", "DEFAULT_BACKEND", "ExecBackend", "OracleBackend",
    "PallasBackend", "ShardedBackend", "available_backends",
    "backend_parity_check",
    "execute_expert_gemm", "execute_gemm", "execute_kv_attention",
    "get_backend", "kv_block_size", "quantize_activations",
    "register_backend",
]
