"""Distributed integer-serving benchmark: 1 -> 2 -> 8 device scaling.

Serves the same calibrated + exported model through
``PagedServingEngine.from_exported`` on a single device and on 2- and
8-way ``("data", "model")`` host meshes (``repro.dist.tp`` shards the
INT8 code banks and KV pools over "model"), and reports per mesh size:

  * decode tokens/s under both wire modes (``int8`` code collectives vs
    the ``fp32`` parity-debug fallback),
  * the per-layer analytic wire-byte table from the engine's
    ``shard_plan`` (``repro.dist.tp.wire_report`` — the SAME static plan
    the executors shard with, so the accounting cannot drift from what
    ran),
  * the aggregate int8/fp32 byte ratio over the switchable collectives.

Two hard gates run before any number is reported (a wrong engine's
throughput is worthless):

  * parity — greedy decodes on every mesh, under BOTH wire modes, must
    be token-identical to the single-device engine;
  * wire — the switchable-collective byte ratio must be >= 3.5x (the
    all-APSQ smoke policy makes every quantized GEMM combine a lossless
    INT8 code gather: exactly 4x fewer bytes than fp32).

Runs on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(set below BEFORE jax initializes, preserving a caller-provided value).
``--smoke`` is the CI shape; ``--json BENCH_dist.json`` emits the
machine-readable record tracked across PRs like the other BENCH files.
"""
import argparse
import json
import os
import platform
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402  (device count must be forced first)
import numpy as np  # noqa: E402

from repro.core import QuantConfig  # noqa: E402
from repro.dist.tp import wire_report  # noqa: E402
from repro.launch.mesh import make_smoke_mesh  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.model import init_lm  # noqa: E402
from repro.quant import calibrate_model  # noqa: E402
from repro.serving import PagedServingEngine, Request  # noqa: E402


def _cfg(smoke: bool) -> ModelConfig:
    # Dims divisible by 8 so the widest mesh shards every bank AND the
    # KV head pools; all-APSQ so every GEMM combine is switchable.
    dm, ff = (64, 128) if smoke else (128, 512)
    return ModelConfig(name="dist-bench", family="dense", n_layers=2,
                       d_model=dm, n_heads=8, n_kv_heads=8, d_ff=ff,
                       vocab=128, dtype="float32", scan_layers=False,
                       quant=QuantConfig.apsq(gs=2, n_p=4))


def _requests(cfg, n, max_new, rng):
    return [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        int(rng.integers(4, 14))
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _serve(params, cfg, reqs, *, mesh=None, wire="int8", max_batch=4):
    eng = PagedServingEngine.from_exported(
        params, cfg, max_batch=max_batch, page_size=8,
        n_pages=16 * max_batch + 1, prefill_chunk=8, backend="auto",
        mesh=mesh, wire=wire)
    eng.run([Request(uid=-1, tokens=reqs[0].tokens.copy(),
                     max_new_tokens=2)])          # compile outside the clock
    t0 = time.perf_counter()
    done = eng.run([Request(uid=r.uid, tokens=r.tokens.copy(),
                            max_new_tokens=r.max_new_tokens) for r in reqs])
    dt = time.perf_counter() - t0
    outs = tuple(tuple(r.out) for r in sorted(done, key=lambda r: r.uid))
    toks = sum(len(o) for o in outs)
    return outs, toks / dt, eng.shard_plan


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    cfg = _cfg(args.smoke)
    rng = np.random.default_rng(0)
    reqs = _requests(cfg, args.requests, args.max_new_tokens, rng)

    params = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    params = calibrate_model(params, cfg, {"tokens": tok})

    n_dev = len(jax.devices())
    sizes = [d for d in (1, 2, 8) if d <= n_dev]
    print(f"[dist_bench] {n_dev} devices -> mesh sizes {sizes}")

    record = {"bench": "dist", "config": cfg.name,
              "host": platform.node(), "n_devices": n_dev, "meshes": {}}
    ref_outs, ref_tps, _ = _serve(params, cfg, reqs)
    record["meshes"]["1"] = {"tokens_per_s": {"int8": ref_tps}}
    print(f"[dist_bench] d=1            {ref_tps:8.1f} tok/s (reference)")

    parity_ok = True
    ratios = []
    for d in sizes:
        if d == 1:
            continue
        mesh = make_smoke_mesh((1, d))
        entry = {"tokens_per_s": {}, "wire": None}
        for wire in ("int8", "fp32"):
            outs, tps, plan = _serve(params, cfg, reqs, mesh=mesh, wire=wire)
            ok = outs == ref_outs
            parity_ok &= ok
            entry["tokens_per_s"][wire] = tps
            print(f"[dist_bench] d={d} wire={wire} {tps:8.1f} tok/s "
                  f"parity={'OK' if ok else 'FAIL'}")
            if wire == "int8":
                wr = wire_report(plan, m=1)
                entry["wire"] = wr
                ratios.append(wr["switchable"]["ratio"])
                print(f"[dist_bench]   wire bytes/decode-step (m=1): "
                      f"switchable int8={wr['switchable']['int8']} "
                      f"fp32={wr['switchable']['fp32']} "
                      f"ratio={wr['switchable']['ratio']:.2f}x; "
                      f"total ratio={wr['total']['ratio']:.2f}x")
        record["meshes"][str(d)] = entry

    min_ratio = min(ratios) if ratios else None
    record["gate"] = {"parity": parity_ok, "switchable_ratio": min_ratio,
                      "ratio_floor": 3.5}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        print(f"[dist_bench] wrote {args.json}")

    if not parity_ok:
        raise SystemExit("dist_bench GATE FAILURE: sharded decode diverged "
                         "from the single-device reference")
    if min_ratio is not None and min_ratio < 3.5:
        raise SystemExit(f"dist_bench GATE FAILURE: switchable int8/fp32 "
                         f"wire ratio {min_ratio:.2f} < 3.5")
    if ratios:
        print(f"[dist_bench] gates OK: parity on {len(sizes) - 1} meshes "
              f"x 2 wire modes; min switchable ratio {min_ratio:.2f}x")
    else:
        print("[dist_bench] single device only — scaling + wire gates "
              "skipped (set XLA_FLAGS=--xla_force_host_platform_"
              "device_count=8)")


if __name__ == "__main__":
    main()
