"""Optimizer, data pipeline, checkpoint: unit + roundtrip tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import DataConfig, PrefetchIterator, SyntheticCorpus
from repro.optim import (
    OptimConfig,
    apply_updates,
    decay_mask,
    init_opt_state,
    lr_schedule,
)


# ------------------------------ optimizer ---------------------------------

def test_adamw_minimizes_quadratic():
    cfg = OptimConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, g, state, cfg,
                                         mask={"w": False})
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_decay_mask_excludes_scales_and_norms():
    params = {
        "layer": {"w": jnp.ones((4, 4)), "qp": {"aw": jnp.ones(4),
                                                "ax": jnp.ones(()),
                                                "ap": jnp.ones(3)}},
        "ln": {"scale": jnp.ones(4), "bias": jnp.zeros(4)},
    }
    m = decay_mask(params)
    assert m["layer"]["w"] is True
    assert m["layer"]["qp"]["aw"] is False
    assert m["ln"]["scale"] is False and m["ln"]["bias"] is False


def test_lr_schedule_warmup_and_decay():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_grad_clipping():
    cfg = OptimConfig(lr=0.0, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, cfg)
    _, _, stats = apply_updates(params, {"w": jnp.asarray([3.0, 4.0, 0.0])},
                                state, cfg, mask={"w": False})
    assert float(stats["grad_norm"]) == pytest.approx(5.0)


def test_adafactor_like_factored_state():
    cfg = OptimConfig(adafactor_like=True, warmup_steps=0, lr=0.01)
    params = {"w": jnp.ones((8, 16)), "b": jnp.ones(8)}
    state = init_opt_state(params, cfg)
    assert set(state["v"]["w"].keys()) == {"row", "col"}
    assert state["v"]["w"]["row"].shape == (8,)
    assert set(state["v"]["b"].keys()) == {"full"}
    g = jax.tree.map(jnp.ones_like, params)
    p2, s2, _ = apply_updates(params, g, state, cfg,
                              mask=jax.tree.map(lambda _: False, params))
    assert float(jnp.max(p2["w"])) < 1.0  # moved


# ------------------------------ data --------------------------------------

def test_batch_at_deterministic():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=4, seed=7)
    c = SyntheticCorpus(cfg)
    b1 = c.batch_at(3)
    b2 = SyntheticCorpus(cfg).batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], c.batch_at(4)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=2)
    b = SyntheticCorpus(cfg).batch_at(0)
    assert b["tokens"].shape == (2, 32) and b["labels"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_batch():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8)
    c = SyntheticCorpus(cfg)
    h0 = c.batch_at(0, host_id=0, num_hosts=2)
    h1 = c.batch_at(0, host_id=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_corpus_has_learnable_structure():
    """Motif copies => top bigrams repeat far above uniform chance."""
    cfg = DataConfig(vocab=4096, seq_len=512, global_batch=4)
    b = SyntheticCorpus(cfg).batch_at(0)
    toks = b["tokens"].reshape(-1)
    bigrams = list(zip(toks[:-1].tolist(), toks[1:].tolist()))
    from collections import Counter
    top = Counter(bigrams).most_common(1)[0][1]
    assert top > 5  # uniform chance would be ~1


def test_prefetch_iterator():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    it = PrefetchIterator(SyntheticCorpus(cfg), start_step=5)
    s, b = next(it)
    assert s == 5 and b["tokens"].shape == (2, 8)
    s, _ = next(it)
    assert s == 6
    it.close()


# ------------------------------ checkpoint --------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "nested": {"b": jnp.ones(4, jnp.bfloat16)}},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, _tree(), extra={"note": "x"})
        tree, manifest = restore(d)
        assert manifest["step"] == 3
        np.testing.assert_array_equal(tree["params"]["w"],
                                      np.arange(6).reshape(2, 3))
        assert tree["params"]["nested"]["b"].dtype == np.dtype("bfloat16") \
            or str(tree["params"]["nested"]["b"].dtype) == "bfloat16"
        assert int(tree["opt"]["step"]) == 7


def test_atomic_overwrite_and_latest():
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, _tree())
        save(d, 5, _tree())
        assert latest_step(d) == 5
        tree, m = restore(d, step=1)
        assert m["step"] == 1


def test_async_checkpointer_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _tree())
        ck.wait()
        ck._gc()
        steps = sorted(int(p.split("-")[1]) for p in os.listdir(d)
                       if p.startswith("step-"))
        assert steps == [3, 4]


def test_restore_with_shardings_device_put():
    with tempfile.TemporaryDirectory() as d:
        save(d, 2, _tree())
        sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        shardings = jax.tree.map(lambda _: sh, _tree())
        tree, _ = restore(d, shardings=shardings)
        assert isinstance(tree["params"]["w"], jax.Array)
