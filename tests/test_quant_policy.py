"""Quantization API v2: per-layer policies, QuantState pytree, capture
calibration, checkpoint upgrade, and integer deployment export."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.core import (
    DeployedQuantState,
    QuantConfig,
    QuantState,
    po2_quantize_codes,
    quant_dense,
    quant_params_init,
)
from repro.dist import tree_specs
from repro.models.config import ModelConfig
from repro.models.model import forward, init_lm, lm_specs
from repro.quant import (
    QuantPolicy,
    calibrate_model,
    export_quantized,
    snap_params_po2,
)

MIX_CFG = QuantConfig.apsq(gs=2, n_p=4)
FFN_CFG = QuantConfig.apsq(gs=4, n_p=8)
POLICY = QuantPolicy.of(
    ("*.mix.*", MIX_CFG),
    ("*.ffn.*", FFN_CFG),
    default=QuantConfig.w8a8(),
)


def _cfg(**kw):
    base = dict(name="qp", family="dense", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=128, dtype="float32",
                scan_layers=False, quant=QuantConfig.apsq(gs=2, n_p=4))
    base.update(kw)
    return ModelConfig(**base)


def _quant_states(tree, out=None):
    out = [] if out is None else out
    if isinstance(tree, QuantState):
        out.append(tree)
    elif isinstance(tree, dict):
        for v in tree.values():
            _quant_states(v, out)
    return out


# ------------------------------ policy resolution --------------------------

def test_policy_precedence_and_fallthrough():
    p = QuantPolicy.of(
        ("unit.0.mix.wq", MIX_CFG),
        ("unit.*", FFN_CFG),
        default=QuantConfig.w8a8(),
    )
    assert p.resolve("unit.0.mix.wq") is MIX_CFG          # first match wins
    assert p.resolve("unit.0.mix.wk") is FFN_CFG          # glob
    assert p.resolve("rem.0.ffn.wi").psum.mode == "none"  # default w8a8
    assert QuantPolicy.of(("unit.*", MIX_CFG)).resolve("rem.0.x") is None


def test_policy_uniform_equals_global_config():
    cfg_global = _cfg()
    cfg_policy = _cfg(quant=QuantConfig(), quant_policy=QuantPolicy.uniform(
        QuantConfig.apsq(gs=2, n_p=4)))
    pg = init_lm(jax.random.PRNGKey(0), cfg_global)
    pp = init_lm(jax.random.PRNGKey(0), cfg_policy)
    for a, b in zip(jax.tree.leaves(pg), jax.tree.leaves(pp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert jax.tree.structure(pg) == jax.tree.structure(pp)


def test_heterogeneous_policy_resolves_per_layer():
    cfg = _cfg(quant=QuantConfig(), quant_policy=POLICY)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    wq = p["units"]["u0"]["0"]["mix"]["wq"]["qp"]
    wi = p["units"]["u0"]["0"]["ffn"]["wi"]["qp"]
    assert wq.spec.psum.gs == 2 and wq.spec.psum.n_p == 4
    assert wi.spec.psum.gs == 4 and wi.spec.psum.n_p == 8
    assert wq.ap.shape == (4,) and wi.ap.shape == (8,)
    assert wq.name == "unit.0.mix.wq" and wi.name == "unit.0.ffn.wi"
    # end-to-end forward with mixed specs
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    lg = jax.jit(lambda pp: forward(pp, cfg, tok))(p)
    assert not bool(jnp.any(jnp.isnan(lg)))


# ------------------------------ QuantState pytree --------------------------

def test_quant_state_dict_access_and_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    qp = quant_params_init(w, QuantConfig.apsq(gs=2, n_p=4), name="lin")
    assert "ap" in qp and "aw" in qp and qp.get("missing") is None
    assert qp["ax"].shape == ()
    # jit round-trip preserves data, spec, and name
    qp2 = jax.jit(lambda q: q)(qp)
    assert isinstance(qp2, QuantState)
    assert qp2.spec == qp.spec and qp2.name == "lin"
    np.testing.assert_array_equal(np.asarray(qp.ap), np.asarray(qp2.ap))
    # effective n_p clamps to a divisor of K and lands in the spec
    qp3 = quant_params_init(w, QuantConfig.apsq(gs=2, n_p=5))
    assert qp3.spec.psum.n_p == 4 and qp3.ap.shape == (4,)


def test_quant_state_under_scan_and_grad():
    cfg = _cfg(scan_layers=True, n_layers=4)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    qp = p["units"]["0"]["mix"]["wq"]["qp"]
    assert isinstance(qp, QuantState) and qp.ap.shape == (4, 4)  # stacked
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)

    def loss(pp):
        return jnp.mean(jnp.square(forward(pp, cfg, tok)))

    g = jax.grad(loss)(p)
    gq = g["units"]["0"]["mix"]["wq"]["qp"]
    assert isinstance(gq, QuantState)  # grads keep the typed structure
    assert gq.ap.shape == (4, 4)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_specs_cover_quantized_params():
    cfg = _cfg(scan_layers=True, n_layers=4)
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    from repro.launch.mesh import make_smoke_mesh
    specs = tree_specs(lm_specs(cfg), shapes, make_smoke_mesh())
    # output mirrors the params structure exactly (jit in_shardings ready)
    assert jax.tree.structure(jax.tree.map(lambda _: 0, specs)) \
        == jax.tree.structure(jax.tree.map(lambda _: 0, shapes))


# ------------------------------ checkpoint ---------------------------------

def test_checkpoint_roundtrips_quant_state():
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, {"params": p})
        tree, manifest = restore(d)
    assert manifest["quant_states"]
    states = _quant_states(tree["params"])
    assert states and all(isinstance(s, QuantState) for s in states)
    orig = {s.name: s for s in _quant_states(p)}
    for s in states:
        assert s.spec == orig[s.name].spec
        np.testing.assert_array_equal(np.asarray(s.ap),
                                      np.asarray(orig[s.name].ap))


def test_checkpoint_upgrades_legacy_dict_params():
    """Pre-API-v2 checkpoints stored raw {"aw","ax","ap"} dicts; restore
    upgrades them when given a policy."""
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)

    def degrade(t):  # what an old checkpoint's tree looked like
        if isinstance(t, QuantState):
            return t.as_dict()
        if isinstance(t, dict):
            return {k: degrade(v) for k, v in t.items()}
        return t

    legacy = degrade(p)
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, {"params": legacy})
        # simulate the old writer: no quantizer metadata in the manifest
        import json, os, glob
        mf = glob.glob(os.path.join(d, "step-*", "manifest.json"))[0]
        m = json.load(open(mf))
        m.pop("quant_states", None)
        json.dump(m, open(mf, "w"))
        tree, _ = restore(d, quant_policy=QuantPolicy.uniform(
            QuantConfig.apsq(gs=2, n_p=4)))
    states = _quant_states(tree["params"])
    assert states and all(isinstance(s, QuantState) for s in states)
    by_name = {s.name: s for s in states}
    assert "unit.0.mix.wq" in by_name
    assert by_name["unit.0.mix.wq"].spec.psum.mode == "apsq"
    # restored tree runs
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg.vocab)
    tree = jax.tree.map(jnp.asarray, tree)
    assert not bool(jnp.any(jnp.isnan(forward(tree["params"], cfg, tok))))


# ------------------------------ calibration --------------------------------

def test_calibrate_reaches_scan_stacked_units():
    """Linears inside lax.scan bodies were silently skipped by the old
    monkey-patching calibration; the capture API reaches all of them."""
    cfg = _cfg(scan_layers=True, n_layers=4)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    before = {s.name: s for s in _quant_states(p)}
    n_updated = 0
    for s in _quant_states(p2):
        b = before[s.name]
        for unit in range(s.ap.shape[0]):  # every unit slice must move
            assert not np.allclose(np.asarray(b.ap[unit]),
                                   np.asarray(s.ap[unit])), (s.name, unit)
        n_updated += 1
    assert n_updated == len(before) > 0
    # purity: the input tree is untouched
    for s in _quant_states(p):
        np.testing.assert_array_equal(np.asarray(s.ap),
                                      np.asarray(before[s.name].ap))
    assert not bool(jnp.any(jnp.isnan(forward(p2, cfg, tok))))


def test_calibrate_reaches_moe_experts():
    cfg = _cfg(mlp="moe", n_experts=4, top_k=2, scan_layers=False)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    before = {s.name: s for s in _quant_states(p)}
    moe_names = [n for n in before if ".ffn.w" in n]
    assert moe_names, "moe expert quantizers missing"
    after = {s.name: s for s in _quant_states(p2)}
    changed = [n for n in moe_names
               if not np.allclose(np.asarray(before[n].ap),
                                  np.asarray(after[n].ap))]
    assert len(changed) == len(moe_names), (changed, moe_names)


# ------------------------------ export -------------------------------------

def test_export_codes_bit_exact_vs_po2_quantize_codes():
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    dep, report = export_quantized(p2)
    lin = p2["units"]["u0"]["0"]["mix"]["wq"]
    dq = dep["units"]["u0"]["0"]["mix"]["wq"]["qp"]
    assert isinstance(dq, DeployedQuantState)
    w2d = lin["w"].reshape(lin["w"].shape[0], -1).astype(jnp.float32)
    codes, exps = po2_quantize_codes(
        w2d, jnp.log2(jnp.maximum(lin["qp"].aw.astype(jnp.float32), 1e-30)))
    np.testing.assert_array_equal(np.asarray(dq.w_codes), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(dq.aw_exp), np.asarray(exps))
    assert report["unit.0.mix.wq"]["mode"] == "apsq"


def test_export_integer_path_bit_exact_vs_kernel_reference():
    """Per-tensor weight scales -> [n_p] exponents, the exact layout the
    Pallas kernel consumes; deployed execution == integer oracle == kernel
    (interpret mode), all driven by export_quantized output."""
    from repro.core import deployed_dense
    from repro.kernels.apsq_matmul import apsq_matmul_int8, apsq_matmul_ref

    cfg = QuantConfig(enabled=True, per_channel_w=False,
                      psum=MIX_CFG.psum)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16)) * 0.1
    from repro.core import calibrate_dense
    qp = calibrate_dense(quant_params_init(w, cfg, name="lin"), x, w)
    dep, _ = export_quantized({"lin": {"w": w, "qp": qp}})
    dq = dep["lin"]["qp"]
    assert dq.psum_exps.ndim == 1  # kernel-compatible layout

    xc = jnp.clip(jnp.round(x / jnp.exp2(dq.ax_exp.astype(jnp.float32))),
                  -128, 127).astype(jnp.int8)
    oracle = apsq_matmul_ref(xc, dq.w_codes, dq.psum_exps,
                             n_p=dq.psum_exps.shape[0], gs=cfg.psum.gs)
    kern = apsq_matmul_int8(xc, dq.w_codes, dq.psum_exps, gs=cfg.psum.gs,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(kern))

    scale = float(jnp.exp2((dq.ax_exp + dq.aw_exp).astype(jnp.float32)))
    got = deployed_dense(x, dq)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(oracle, np.float32) * scale,
                               rtol=0, atol=0)


def test_deployed_model_matches_snapped_fakequant():
    """Integer deployment == fake-quant reference on the exported PO2
    grid, up to the shifter rounding mode (<= 2 LSB per PSUM quantizer,
    same bound as test_system's kernel-agreement test)."""
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    dep, _ = export_quantized(p2)
    snapped = snap_params_po2(p2)
    lg_dep = forward(dep, cfg, tok)
    lg_fake = forward(snapped, cfg, tok)
    err = float(jnp.max(jnp.abs(lg_dep - lg_fake)))
    ref = float(jnp.max(jnp.abs(lg_fake))) + 1e-6
    assert err / ref < 0.05, (err, ref)


def test_exported_engine_matches_fakequant_engine():
    """ServingEngine consumes the export directly; greedy decode matches
    the snapped fake-quant engine token-for-token on a smoke model."""
    from repro.serving import Request, ServingEngine
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})

    prompt = np.arange(6) % cfg.vocab
    eng_int = ServingEngine.from_exported(p2, cfg, max_batch=1, cache_len=64,
                                          prefill_chunk=8)
    done_int = eng_int.run([Request(uid=0, tokens=prompt, max_new_tokens=5)])
    eng_fake = ServingEngine(snap_params_po2(p2), cfg, max_batch=1,
                             cache_len=64, prefill_chunk=8)
    done_fake = eng_fake.run([Request(uid=0, tokens=prompt,
                                      max_new_tokens=5)])
    assert done_int[0].out == done_fake[0].out


def test_checkpoint_upgrade_keeps_params_and_moments_compatible():
    """Legacy trainer checkpoints carry {'params', 'opt'} where the adam
    moments mirror the param tree; the upgrade must give both the same
    QuantState metadata (it is treedef aux data) or tree.map over
    (params, m) explodes."""
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)

    def degrade(t):
        if isinstance(t, QuantState):
            return t.as_dict()
        if isinstance(t, dict):
            return {k: degrade(v) for k, v in t.items()}
        return t

    legacy_p = degrade(p)
    legacy_m = jax.tree.map(jnp.zeros_like, legacy_p)
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, {"params": legacy_p, "opt": {"m": legacy_m}})
        import json, os, glob
        mf = glob.glob(os.path.join(d, "step-*", "manifest.json"))[0]
        m = json.load(open(mf))
        m.pop("quant_states", None)
        json.dump(m, open(mf, "w"))
        tree, _ = restore(d, quant_policy=QuantPolicy.uniform(
            QuantConfig.apsq(gs=2, n_p=4)))
    # identical treedefs -> two-tree map works (the optimizer update path)
    jax.tree.map(lambda a, b: a, tree["params"], tree["opt"]["m"])
    names_p = {s.name for s in _quant_states(tree["params"])}
    names_m = {s.name for s in _quant_states(tree["opt"]["m"])}
    assert names_p == names_m and "unit.0.mix.wq" in names_p


def test_checkpoint_roundtrips_deployed_tree():
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    dep, _ = export_quantized(calibrate_model(p, cfg, {"tokens": tok}))
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, dep)
        tree, manifest = restore(d)
    kinds = {m["kind"] for m in manifest["quant_states"].values()}
    assert kinds == {"DeployedQuantState"}
    tree = jax.tree.map(jnp.asarray, tree)
    lg_a = forward(dep, cfg, tok)
    lg_b = forward(tree, cfg, tok)
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))


def test_export_override_accepts_effective_n_p():
    """A policy whose n_p was clamped at init (non-divisor of K) must be
    re-usable verbatim at export time."""
    cfg = QuantConfig.apsq(gs=2, n_p=5)  # K=16 -> effective n_p = 4
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8)) * 0.1
    qp = quant_params_init(w, cfg, name="lin")
    assert qp.spec.psum.n_p == 4
    dep, report = export_quantized(
        {"lin": {"w": w, "qp": qp}},
        policy=QuantPolicy.uniform(QuantConfig.apsq(gs=2, n_p=5)))
    assert report["lin"]["n_p"] == 4


def test_export_override_rejects_uncalibrated_psum():
    """Upgrading a w8a8-calibrated layer to apsq at export time cannot
    synthesize PSUM scales; it must fail loudly, not silently deploy
    baseline W8A8 under an 'apsq' label."""
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8)) * 0.1
    qp = quant_params_init(w, QuantConfig.w8a8(), name="lin")
    with pytest.raises(ValueError, match="calibrated without PSUM"):
        export_quantized({"lin": {"w": w, "qp": qp}},
                         policy=QuantPolicy.uniform(
                             QuantConfig.apsq(gs=2, n_p=4)))


def test_export_policy_override_and_per_layer_gs():
    """Re-deploy with a different gs per layer group without retraining
    (n_p must match the calibrated tiling)."""
    cfg = _cfg(quant=QuantConfig(), quant_policy=POLICY)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    override = QuantPolicy.of(
        ("*.mix.*", QuantConfig.apsq(gs=4, n_p=4)),   # same n_p, new gs
        default=None)
    dep, report = export_quantized(p2, policy=override)
    assert report["unit.0.mix.wq"]["gs"] == 4
    assert report["unit.0.ffn.wi"]["gs"] == 4         # FFN untouched (gs=4)
    assert not bool(jnp.any(jnp.isnan(forward(dep, cfg, tok))))
    bad = QuantPolicy.of(("*.mix.*", QuantConfig.apsq(gs=2, n_p=8)))
    with pytest.raises(ValueError):
        export_quantized(p2, policy=bad)
