"""qwen3-moe-235b-a22b — Qwen3 MoE 235B total / 22B active
[hf:Qwen/Qwen3-30B-A3B family scaling; hf].

94L d_model=4096 64H (GQA kv=4, head_dim=128) expert d_ff=1536
vocab=151936, MoE 128 experts top-8.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    norm="rmsnorm",
    mlp="moe",
    n_experts=128,
    top_k=8,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64, vocab=256,
        mlp="moe", n_experts=8, top_k=2, dtype="float32")
