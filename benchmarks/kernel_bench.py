"""Kernel benchmark (§III-C): APSQ Pallas kernel vs references.

On this CPU container the kernel runs in interpret mode, so wall-clock is
not a TPU signal; what we measure and report:
  * bit-exactness vs the integer oracle across a shape sweep,
  * accumulator traffic (bytes) of APSQ banks vs the INT32 baseline —
    the quantity the paper's energy claim rides on (beta 4 -> 1),
  * throughput of the jitted *fake-quant* APSQ GEMM vs plain GEMM on CPU
    (QAT-time overhead of the technique).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantConfig, quant_dense, quant_params_init, \
    calibrate_dense
from repro.kernels.apsq_matmul import (
    accumulator_vmem_bytes,
    apsq_matmul_int8,
    apsq_matmul_ref,
    choose_exps,
)

from .common import timed


def run(print_fn=print):
    key = jax.random.PRNGKey(0)
    # 1. correctness sweep (interpret mode)
    ok = 0
    for (m, k, n, n_p, gs) in [(32, 128, 64, 8, 2), (64, 256, 128, 4, 4),
                               (16, 64, 32, 8, 1), (128, 512, 128, 16, 3)]:
        x = jax.random.randint(key, (m, k), -128, 128, jnp.int8)
        w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -128,
                               128, jnp.int8)
        exps = choose_exps(x, w, n_p=n_p, gs=gs)
        ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
        out = apsq_matmul_int8(x, w, exps, gs=gs, interpret=True)
        assert np.array_equal(np.asarray(ref), np.asarray(out))
        ok += 1
    print_fn(f"kernel,bit_exact_cells={ok}/4")

    # 2. accumulator bytes: the beta 4->1 story per output tile
    for gs in (1, 2, 4):
        v = accumulator_vmem_bytes(128, 128, gs)
        print_fn(f"kernel,accumulator_bytes,gs={gs},"
                 f"apsq={v['apsq_banks']},int32={v['baseline_int32']},"
                 f"saving={1 - v['apsq_banks'] / v['baseline_int32']:.2f}")

    # 3. QAT-time overhead of fake-quant APSQ vs plain matmul (CPU)
    xf = jax.random.normal(key, (256, 1024))
    wf = jax.random.normal(jax.random.fold_in(key, 2), (1024, 512)) * 0.05
    cfg = QuantConfig.apsq(gs=2, n_p=8)
    qp = calibrate_dense(quant_params_init(wf, cfg), xf, wf, cfg)

    plain = jax.jit(lambda a, b: a @ b)
    apsq = jax.jit(lambda a, b: quant_dense(a, b, qp, cfg))
    t0, _ = timed(plain, xf, wf)
    t1, y = timed(apsq, xf, wf)
    rel = float(jnp.mean(jnp.abs(y - xf @ wf)) /
                jnp.mean(jnp.abs(xf @ wf)))
    print_fn(f"kernel,qat_overhead,plain_us={t0:.0f},apsq_us={t1:.0f},"
             f"x{t1 / t0:.1f},rel_err={rel:.4f}")

    # 4. INT8 KV-cache decode attention (second kernel): accuracy vs fp32
    #    reference + the bandwidth story (decode cells are HBM-bound).
    from repro.kernels.int8_kv_attention import (
        cache_bytes, fp_attention_ref, int8_kv_attention_f32)
    q = jax.random.normal(key, (2, 8, 64))
    kv = jax.random.normal(jax.random.fold_in(key, 3), (2, 256, 2, 64))
    vv = jax.random.normal(jax.random.fold_in(key, 4), (2, 256, 2, 64))
    L = jnp.full((2,), 256, jnp.int32)
    fp = fp_attention_ref(q, kv, vv, L)
    out = int8_kv_attention_f32(q, kv, vv, L, block_s=128, interpret=True)
    rel = float(jnp.mean(jnp.abs(out - fp)) / jnp.mean(jnp.abs(fp)))
    cb = cache_bytes(128, 32768, 4, 128)  # tinyllama decode_32k cell
    print_fn(f"kernel,int8_kv_attention,rel_err_vs_fp32={rel:.4f},"
             f"decode32k_cache_bytes: bf16={cb['bf16']:.2e} -> "
             f"int8={cb['int8']:.2e} ({cb['int8'] / cb['bf16']:.2f}x)")
    return ok


if __name__ == "__main__":
    run()
