"""Table IV: LLaMA2-7B normalized energy, IS + WS, MAC-preserving decode
simulation (P_o=1, P_ci=P_co=32) at seq 4096; plus the physical
per-token autoregressive walk as a reality check."""
from repro.energy import (
    AcceleratorConfig,
    llama2_7b_autoregressive,
    llama2_7b_combined,
    model_energy,
)


def run(print_fn=print):
    acc = AcceleratorConfig.llm_decode()
    layers = llama2_7b_combined(4096)
    out = {}
    for df in ("IS", "WS"):
        base = model_energy(layers, acc, df, psum_bits=32)
        row = []
        for gs in (1, 2, 3, 4):
            e = model_energy(layers, acc, df, psum_bits=8, gs=gs)
            row.append(base["total"] / e["total"])
        out[df] = row
        print_fn(f"table4,{df},baseline_vs_apsq:" +
                 ",".join(f"gs{g}={r:.2f}x"
                          for g, r in zip((1, 2, 3, 4), row)))
    print_fn("table4,paper,WS gs1/2=31.7x gs3/4=3.76x; IS=1.02x")

    # Reality check: true autoregressive decode is weight-DRAM-bound.
    ar = llama2_7b_autoregressive(4096)
    b = model_energy(ar, acc, "WS", psum_bits=32)
    a = model_energy(ar, acc, "WS", psum_bits=8, gs=2)
    print_fn(f"table4,autoregressive_check,WS per-token walk: "
             f"{b['total'] / a['total']:.3f}x (weight-bound, as expected)")
    return out


if __name__ == "__main__":
    run()
