"""recurrentgemma-2b — RecurrentGemma / Griffin 2B [arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 vocab=256000.
Pattern: (RG-LRU, RG-LRU, local-attention) — recurrent:attention 2:1,
local window 2048.  Sub-quadratic: runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    norm="rmsnorm",
    mlp="gelu",
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    d_rnn=2560,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid", n_layers=3,
        d_model=64, n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128,
        vocab=256, mlp="gelu",
        block_pattern=("rglru", "rglru", "local"), local_window=16,
        d_rnn=64, dtype="float32")
