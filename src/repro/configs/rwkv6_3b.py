"""rwkv6-3b — RWKV-6 "Finch" 3B [arXiv:2404.05892; hf].

32L d_model=2560 (attention-free), d_ff=8960, vocab=65536.
Data-dependent decay WKV recurrence; head_dim 64 => 40 heads.
Sub-quadratic: runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    norm="layernorm",
    mlp="rwkv_cm",
    block_pattern=("rwkv",),
    wkv_impl="chunked",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128, vocab=256,
        norm="layernorm", mlp="rwkv_cm", block_pattern=("rwkv",),
        wkv_impl="chunked", dtype="float32")
