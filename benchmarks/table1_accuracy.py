"""Table I: QAT accuracy across Baseline / APSQ gs=1..4 / PSQ.

The paper's GLUE/ADE20K tasks need pretrained checkpoints + datasets that
are unavailable offline; the reproduction target is the *claim structure*:
  * full INT8 PSUM quantization trains near-losslessly vs the W8A8 baseline,
  * gs=1 is the worst APSQ setting,
  * grouping (gs>1) recovers accuracy.
Metric: eval cross-entropy on held-out synthetic batches (lower = better).
"""
from .common import QAT_CFG, quant_variants, train_qat


def run(print_fn=print, steps: int = 60):
    results = {}
    for name, q in quant_variants(n_p=8).items():
        cfg = QAT_CFG.with_quant(q)
        tr, ev = train_qat(cfg, steps=steps)
        results[name] = ev
        print_fn(f"table1,{name},eval_loss={ev:.4f},train_loss={tr:.4f}")
    base = results["baseline_w8a8"]
    worst = results["apsq_gs1"]
    best_gs = min(results[f"apsq_gs{g}"] for g in (2, 3, 4))
    print_fn(f"table1,headline,gs1 gap={worst - base:+.4f},"
             f"best-gs gap={best_gs - base:+.4f} "
             f"(paper: gs=1 notably worse; gs>1 near-lossless)")
    return results


if __name__ == "__main__":
    run()
