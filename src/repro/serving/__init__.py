"""Serving: continuous-batching engines (dense + paged INT8 KV cache)
with fused multi-step decode (``decode_horizon`` macro-steps)."""
from .engine import (
    PagedServingEngine,
    Request,
    ServingEngine,
    dequantize_kv,
    quantize_kv,
)
from .paged_cache import page_span, paged_cache_bytes
from .scheduler import PageAllocator, Scheduler

__all__ = [
    "PageAllocator", "PagedServingEngine", "Request", "Scheduler",
    "ServingEngine", "dequantize_kv", "page_span", "paged_cache_bytes",
    "quantize_kv",
]
