"""QAT integration: per-layer policies, calibration, distillation, export.

``policy`` is imported eagerly (it depends only on ``repro.core``); the
calibration/export modules are loaded lazily via PEP 562 because they pull
in the model zoo / kernels, which themselves import ``repro.quant.policy``.
"""
from .policy import QuantPolicy, QuantRule, resolve_quant

_QAT = ("SweepResult", "calibrate_model", "distill_loss",
        "make_distill_loss_fn", "policy_presets", "quant_variants")
_EXPORT = ("export_quantized", "snap_params_po2")

__all__ = ["QuantPolicy", "QuantRule", "resolve_quant", *_QAT, *_EXPORT]


def __getattr__(name):
    if name in _QAT:
        from . import qat
        return getattr(qat, name)
    if name in _EXPORT:
        from . import export
        return getattr(export, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
