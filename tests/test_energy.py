"""Analytical energy model: eq (1)-(6) invariants + paper-pattern checks."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.energy import (
    AcceleratorConfig,
    LayerEnergySpec,
    LayerShape,
    access_counts,
    bert_base,
    efficientvit_b1,
    layer_energy,
    llama2_7b_combined,
    model_energy,
    savings,
    segformer_b0,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

ACC = AcceleratorConfig()


def test_os_has_zero_psum_traffic():
    l = LayerShape("x", 128, 768, 768)
    c = access_counts(l, ACC, "OS", beta=4.0)
    assert c["sram"]["p"] == 0 and c["dram"]["p"] == 0


@given(st.sampled_from(["IS", "WS"]), st.integers(64, 4096),
       st.integers(64, 4096))
def test_psum_energy_monotonic_in_beta(df, ci, co):
    l = LayerShape("x", 128, ci, co)
    e8 = layer_energy(l, ACC, df, psum_bits=8)
    e16 = layer_energy(l, ACC, df, psum_bits=16)
    e32 = layer_energy(l, ACC, df, psum_bits=32)
    assert e8["psum"] <= e16["psum"] <= e32["psum"]
    assert e8["total"] <= e32["total"]


@given(st.sampled_from(["IS", "WS"]))
def test_non_psum_terms_independent_of_beta(df):
    l = LayerShape("x", 256, 1024, 1024)
    e8 = layer_energy(l, ACC, df, psum_bits=8)
    e32 = layer_energy(l, ACC, df, psum_bits=32)
    for k in ("weight", "op"):
        assert e8[k] == e32[k]


def test_gs_only_affects_capacity_not_counts():
    """Paper §III-B: grouping keeps total access counts identical."""
    l = LayerShape("x", 128, 768, 768)  # fits buffer at any gs <= 4
    for gs in (1, 2, 3, 4):
        c = access_counts(l, ACC, "WS", beta=1.0, gs=gs)
        c1 = access_counts(l, ACC, "WS", beta=1.0, gs=1)
        assert c["sram"] == c1["sram"] and c["dram"] == c1["dram"]


def test_gs_cliff_when_buffer_overflows():
    """Large ofmap rows: gs pushes the live PSUM set past B_o -> DRAM."""
    l = LayerShape("x", 16384, 256, 256)  # Segformer stage-1 like
    e2 = layer_energy(l, ACC, "WS", psum_bits=8, gs=2)
    e3 = layer_energy(l, ACC, "WS", psum_bits=8, gs=3)
    assert e3["psum"] > 2 * e2["psum"]


def test_bert_ws_psum_share_significant():
    """Fig 1: PSUM is a large share of IS/WS energy at INT32."""
    e = model_energy(bert_base(128), ACC, "WS", psum_bits=32)
    assert e["psum"] / e["total"] > 0.4
    e_os = model_energy(bert_base(128), ACC, "OS", psum_bits=32)
    assert e_os["psum"] == 0.0


def test_segformer_cliff_at_gs3():
    """Fig 6: Segformer-B0 WS savings drop at gs >= 3."""
    base = model_energy(segformer_b0(), ACC, "WS", psum_bits=32)
    s = [savings(base, model_energy(segformer_b0(), ACC, "WS",
                                    psum_bits=8, gs=g))
         for g in (1, 2, 3, 4)]
    assert s[0] == pytest.approx(s[1], abs=0.01)   # gs=1,2 equal
    assert s[2] < s[1] - 0.1                        # cliff at gs=3
    assert s[2] == pytest.approx(s[3], abs=0.01)   # gs=3,4 equal


def test_efficientvit_cliff_at_gs3():
    base = model_energy(efficientvit_b1(), ACC, "WS", psum_bits=32)
    s = [savings(base, model_energy(efficientvit_b1(), ACC, "WS",
                                    psum_bits=8, gs=g))
         for g in (1, 2, 3, 4)]
    assert s[2] < s[1] - 0.05


def test_llama_tableiv_pattern():
    """Table IV: WS baseline >> APSQ; IS ~ 1x; gs 3/4 partial regression."""
    acc = AcceleratorConfig.llm_decode()
    layers = llama2_7b_combined(4096)
    base_ws = model_energy(layers, acc, "WS", psum_bits=32)
    a1 = model_energy(layers, acc, "WS", psum_bits=8, gs=1)
    a3 = model_energy(layers, acc, "WS", psum_bits=8, gs=3)
    assert base_ws["total"] / a1["total"] > 10      # paper: 31.7x
    r3 = base_ws["total"] / a3["total"]
    assert 1.5 < r3 < base_ws["total"] / a1["total"]  # paper: 3.76x

    base_is = model_energy(layers, acc, "IS", psum_bits=32)
    ai = model_energy(layers, acc, "IS", psum_bits=8, gs=1)
    assert base_is["total"] / ai["total"] < 1.1     # paper: 1.02x


# ---------------------------------------------------------------------------
# Heterogeneous per-layer model (LayerEnergySpec): the repro.search substrate
# ---------------------------------------------------------------------------

def test_heterogeneous_psum_bits_sum_correctly():
    """model_energy over mixed-psum_bits specs == sum of layer_energy."""
    l1 = LayerShape("a", 128, 768, 3072)
    l2 = LayerShape("b", 128, 3072, 768)
    specs = [LayerEnergySpec(l1, psum_bits=8, gs=2),
             LayerEnergySpec(l2, psum_bits=32, gs=1)]
    tot = model_energy(specs, ACC, "WS")
    e1 = layer_energy(l1, ACC, "WS", psum_bits=8, gs=2)
    e2 = layer_energy(l2, ACC, "WS", psum_bits=32, gs=1)
    for k in ("psum", "total", "ifmap", "weight", "ofmap", "op"):
        assert tot[k] == pytest.approx(e1[k] + e2[k])
    # and the mixed total sits strictly between the two uniform extremes
    uni8 = model_energy([l1, l2], ACC, "WS", psum_bits=8, gs=2)
    uni32 = model_energy([l1, l2], ACC, "WS", psum_bits=32)
    assert uni8["psum"] < tot["psum"] < uni32["psum"]


def test_plain_shapes_and_specs_mix_in_one_walk():
    """A LayerShape entry takes the uniform kwargs; a spec its own."""
    l = LayerShape("x", 128, 768, 768)
    mixed = model_energy([l, LayerEnergySpec(l, psum_bits=8, gs=2)],
                         ACC, "WS", psum_bits=32)
    e32 = layer_energy(l, ACC, "WS", psum_bits=32)
    e8 = layer_energy(l, ACC, "WS", psum_bits=8, gs=2)
    assert mixed["total"] == pytest.approx(e32["total"] + e8["total"])


def test_per_layer_dataflow_override():
    """A spec pinning OS contributes zero PSUM traffic in a WS walk."""
    l = LayerShape("x", 128, 768, 768)
    specs = [LayerEnergySpec(l, psum_bits=8, gs=1, dataflow="OS")]
    e = model_energy(specs, ACC, "WS")
    assert e["psum"] == 0.0


def test_per_layer_gs_cliff_segformer_class():
    """Fig. 6 cliff, per layer: only the big-ofmap layer pays gs=3.

    Segformer/EfficientViT-class stage-1 shapes (16k+ tokens, narrow
    channels) overflow B_o once gs >= 3 INT8 PSUM tile sets are live;
    a small layer in the same walk at gs=3 must NOT pay it.
    """
    big = LayerShape("seg_s0", 16384, 256, 256)     # Segformer stage-1
    small = LayerShape("ffn", 128, 768, 768)        # fits at any gs <= 4
    e_big2 = model_energy([LayerEnergySpec(big, psum_bits=8, gs=2)],
                          ACC, "WS")
    e_big3 = model_energy([LayerEnergySpec(big, psum_bits=8, gs=3)],
                          ACC, "WS")
    assert e_big3["psum"] > 2 * e_big2["psum"]      # DRAM spill cliff
    assert e_big3["dram_bytes"] > e_big2["dram_bytes"]
    e_sm2 = model_energy([LayerEnergySpec(small, psum_bits=8, gs=2)],
                         ACC, "WS")
    e_sm3 = model_energy([LayerEnergySpec(small, psum_bits=8, gs=3)],
                         ACC, "WS")
    assert e_sm3["psum"] == pytest.approx(e_sm2["psum"])
    # heterogeneous walk = its layers' sum (the cliff stays per-layer)
    het = model_energy([LayerEnergySpec(big, psum_bits=8, gs=2),
                        LayerEnergySpec(small, psum_bits=8, gs=3)],
                       ACC, "WS")
    assert het["psum"] == pytest.approx(e_big2["psum"] + e_sm3["psum"])


def test_efficientvit_class_cliff_gs3_heterogeneous():
    """EfficientViT-B1-class walk via specs reproduces the gs>=3 cliff."""
    layers = efficientvit_b1()
    s = []
    base = model_energy(layers, ACC, "WS", psum_bits=32)
    for g in (2, 3):
        specs = [LayerEnergySpec(l, psum_bits=8, gs=g) for l in layers]
        s.append(savings(base, model_energy(specs, ACC, "WS")))
    assert s[1] < s[0] - 0.05


def test_n_p_override_scales_psum_traffic():
    """More PSUM tiles along K -> strictly more PSUM buffer traffic."""
    l = LayerShape("x", 128, 768, 768)
    e_hw = layer_energy(l, ACC, "WS", psum_bits=8)           # n_p = 96
    e_fine = layer_energy(l, ACC, "WS", psum_bits=8, n_p=192)
    e_coarse = layer_energy(l, ACC, "WS", psum_bits=8, n_p=48)
    assert e_coarse["psum"] < e_hw["psum"] < e_fine["psum"]
    for k in ("weight", "ifmap", "op"):                      # psum-only knob
        assert e_coarse[k] == e_hw[k] == e_fine[k]


def test_savings_in_paper_band():
    """Headline: 28-87% (IS low end, WS Segformer high end) -> we accept a
    generous band around the paper's numbers (constants differ)."""
    base = model_energy(segformer_b0(), ACC, "WS", psum_bits=32)
    s = savings(base, model_energy(segformer_b0(), ACC, "WS", psum_bits=8,
                                   gs=2))
    assert 0.6 < s < 0.97
    base = model_energy(bert_base(128), ACC, "WS", psum_bits=32)
    s = savings(base, model_energy(bert_base(128), ACC, "WS", psum_bits=8,
                                   gs=2))
    assert 0.25 < s < 0.6
