"""Paged INT8 KV serving: cache semantics, scheduler invariants, engine parity.

The acceptance bar for the continuous-batching subsystem: greedy outputs
of the batched ``PagedServingEngine`` are token-identical to the
single-stream engine — under admission churn, a dry page pool with
mid-decode eviction, and across exec backends (oracle vs interpret-mode
Pallas).  The host-side scheduler never leaks a slot or a page.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.model import (
    decode_step_paged,
    forward_paged_chunk,
    init_lm,
    init_paged_decode_state,
)
from repro.serving import PagedServingEngine, Request
from repro.serving.paged_cache import (
    EXP_FLOOR,
    NULL_PAGE,
    paged_cache_bytes,
    paged_update_and_attend,
)
from repro.serving.scheduler import PageAllocator, Scheduler

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                  dtype="float32")


def _prompt(n, seed=0):
    return ((np.arange(n) * 7 + seed * 13) % CFG.vocab).astype(np.int32)


def _engine(params, **kw):
    kw.setdefault("backend", "oracle")
    kw.setdefault("prefill_chunk", 8)
    return PagedServingEngine(params, CFG, **kw)


# ---------------------------------------------------------------------------
# Paged cache semantics (device level)
# ---------------------------------------------------------------------------

def _fresh_cache(batch, n_pages, page_size, hkv, hd):
    return {"k_pages": jnp.zeros((n_pages, page_size, hkv, hd), jnp.int8),
            "v_pages": jnp.zeros((n_pages, page_size, hkv, hd), jnp.int8),
            "k_exp": jnp.full((batch, hkv), EXP_FLOOR, jnp.int32),
            "v_exp": jnp.full((batch, hkv), EXP_FLOOR, jnp.int32)}


def test_paged_attend_tracks_fp_reference():
    """Stream tokens through the paged cache; each step's output stays
    within INT8-cache noise of exact fp attention over the same prefix."""
    from repro.kernels.int8_kv_attention import fp_attention_ref
    key = jax.random.PRNGKey(0)
    T, hkv, hd, hq, P = 10, 2, 16, 4, 4
    ks = jax.random.normal(key, (1, T, hkv, hd))
    vs = jax.random.normal(jax.random.fold_in(key, 1), (1, T, hkv, hd))
    qs = jax.random.normal(jax.random.fold_in(key, 2), (T, 1, hq, hd))
    cache = _fresh_cache(1, 8, P, hkv, hd)
    table = jnp.asarray([[1, 2, 3]])      # 3 pages = 12 positions
    for t in range(T):
        out, cache = paged_update_and_attend(
            cache, qs[t], ks[:, t:t + 1], vs[:, t:t + 1],
            jnp.asarray([t], jnp.int32), table, backend="oracle")
        fp = fp_attention_ref(qs[t], ks[:, :t + 1], vs[:, :t + 1],
                              jnp.asarray([t + 1], jnp.int32))
        rel = float(jnp.mean(jnp.abs(out - fp)) /
                    jnp.maximum(jnp.mean(jnp.abs(fp)), 1e-9))
        assert rel < 0.06, (t, rel)
    # running exponents cover the stream and never sit below the floor
    assert int(jnp.min(cache["k_exp"])) > EXP_FLOOR


def test_paged_cache_slot_isolated_from_pool_neighbors():
    """A slot's output depends only on its own tokens: junk written by a
    co-resident slot (different pages, own exponents) changes nothing —
    the property that makes batched decode token-identical."""
    key = jax.random.PRNGKey(1)
    hkv, hd, hq, P = 2, 8, 4, 4
    k1 = jax.random.normal(key, (1, 1, hkv, hd))
    v1 = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, hkv, hd))
    q1 = jax.random.normal(jax.random.fold_in(key, 2), (1, hq, hd))

    solo = _fresh_cache(1, 8, P, hkv, hd)
    out_solo, _ = paged_update_and_attend(
        solo, q1, k1, v1, jnp.asarray([0]), jnp.asarray([[1]]),
        backend="oracle")

    both = _fresh_cache(2, 8, P, hkv, hd)
    k2 = jnp.concatenate([k1, k1 * 100.0])   # neighbor with huge scale
    v2 = jnp.concatenate([v1, v1 * 100.0])
    q2 = jnp.concatenate([q1, q1])
    out_both, _ = paged_update_and_attend(
        both, q2, k2, v2, jnp.asarray([0, 5]), jnp.asarray([[1], [2]]),
        backend="oracle")
    np.testing.assert_array_equal(np.asarray(out_solo[0]),
                                  np.asarray(out_both[0]))


def test_paged_cache_bytes_accounting():
    b = paged_cache_bytes(CFG, n_pages=33, page_size=16, max_batch=8,
                          cache_len=64)
    assert b["n_attn_layers"] == CFG.n_layers
    assert b["int8_paged"] < b["dense_f32"]


# ---------------------------------------------------------------------------
# Scheduler (host level)
# ---------------------------------------------------------------------------

def test_page_allocator_conserved_under_churn():
    alloc = PageAllocator(17)
    rng = np.random.default_rng(0)
    for _ in range(200):
        slot = int(rng.integers(0, 4))
        if rng.random() < 0.6:
            alloc.alloc(slot, int(rng.integers(1, 4)))
        else:
            alloc.release(slot)
        alloc.assert_conserved()
    for s in range(4):
        alloc.release(s)
    alloc.assert_conserved()
    assert alloc.n_free == 16           # every page back, page 0 reserved
    assert NULL_PAGE == 0


def test_scheduler_no_leak_after_evict_and_finish():
    sched = Scheduler(max_slots=2, n_pages=9, page_size=4)
    for i in range(3):
        sched.submit(Request(uid=i, tokens=np.arange(6), max_new_tokens=4))
    s0, r0, _ = sched.admit_next()
    s1, r1, _ = sched.admit_next()
    assert sched.admit_next() is None   # no free slot
    sched.assert_invariants()
    assert sched.grow(s0, 8)            # next page for slot 0
    victim = sched.evict_candidate()
    assert victim == s1                 # latest admitted
    sched.preempt(victim)
    sched.assert_invariants()
    assert sched.waiting[0].uid == r1.uid   # requeued at the front
    assert sched.table[victim].tolist() == [NULL_PAGE] * sched.table.shape[1]
    sched.finish(s0)
    sched.assert_invariants()
    assert sched.alloc.n_free == 8      # all pages back

    # a request that can never fit is rejected up front
    with pytest.raises(ValueError):
        sched.submit(Request(uid=9, tokens=np.arange(40),
                             max_new_tokens=40))


# ---------------------------------------------------------------------------
# Engine parity (the acceptance bar)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _single_stream(params, req_spec):
    eng = _engine(params, max_batch=1, page_size=4, n_pages=32)
    outs = {}
    for uid, toks, n in req_spec:
        r = Request(uid=uid, tokens=toks, max_new_tokens=n)
        eng.run([r])
        outs[uid] = r.out
    return outs


def test_batched_matches_single_stream(params):
    spec = [(i, _prompt(4 + i, seed=i), 6) for i in range(6)]
    single = _single_stream(params, spec)
    eng = _engine(params, max_batch=3, page_size=4, n_pages=32)
    done = eng.run([Request(uid=u, tokens=t, max_new_tokens=n)
                    for u, t, n in spec])
    assert {r.uid: r.out for r in done} == single
    eng.sched.assert_invariants()


def test_eviction_mid_decode_keeps_outputs_identical(params):
    """Pool far too small for 4 concurrent slots: requests get preempted
    mid-decode and resumed, yet every output matches the roomy engine."""
    spec = [(i, _prompt(5 + i, seed=i), 8) for i in range(4)]
    single = _single_stream(params, spec)
    eng = _engine(params, max_batch=4, page_size=4, n_pages=10)
    done = eng.run([Request(uid=u, tokens=t, max_new_tokens=n)
                    for u, t, n in spec])
    assert eng.sched.stats.preempted > 0, "pool was not small enough"
    assert {r.uid: r.out for r in done} == single
    eng.sched.assert_invariants()
    assert eng.sched.alloc.n_free == 9  # every page reclaimed


def test_paged_eos_token_stops_stream(params):
    probe = Request(uid=0, tokens=_prompt(6), max_new_tokens=6)
    _engine(params, max_batch=1, page_size=8, n_pages=16).run([probe])
    eos = probe.out[2]
    r = Request(uid=1, tokens=_prompt(6), max_new_tokens=50, eos_token=eos)
    _engine(params, max_batch=1, page_size=8, n_pages=16).run([r])
    expect = probe.out[:probe.out.index(eos) + 1]  # first occurrence stops
    assert r.out == expect and r.done


def test_paged_engine_pallas_matches_oracle(params):
    from repro.exec import PallasBackend
    spec = [(0, _prompt(6), 5), (1, _prompt(9, seed=2), 5)]
    outs = {}
    for be in ("oracle", PallasBackend(interpret=True)):
        eng = _engine(params, max_batch=2, page_size=8, n_pages=16,
                      backend=be)
        done = eng.run([Request(uid=u, tokens=t, max_new_tokens=n)
                        for u, t, n in spec])
        outs[str(be)] = {r.uid: r.out for r in done}
    vals = list(outs.values())
    assert vals[0] == vals[1]


# ---------------------------------------------------------------------------
# Chunked prefill: bit-parity with the token-by-token scan
# ---------------------------------------------------------------------------

def _scan_vs_chunk(cfg, params, L, chunks, backend, page_size=4, n_pages=16):
    """Prefill ``L`` prompt tokens token-by-token and as ``chunks``;
    return (states bit-equal, greedy next tokens equal)."""
    toks = ((np.arange(L) * 7 + 3) % cfg.vocab).astype(np.int32)
    n_slot_pages = -(-(L + 1) // page_size)
    table = jnp.asarray(np.arange(1, n_slot_pages + 1)[None])

    st_a = init_paged_decode_state(cfg, 1, page_size=page_size,
                                   n_pages=n_pages)
    for t in range(L):
        lg_a, st_a = decode_step_paged(
            params, cfg, st_a, jnp.asarray([[toks[t]]]),
            jnp.asarray([t]), table, backend=backend)

    st_b = init_paged_decode_state(cfg, 1, page_size=page_size,
                                   n_pages=n_pages)
    done = 0
    for c in chunks:
        lg_b, st_b = forward_paged_chunk(
            params, cfg, st_b, jnp.asarray(toks[done:done + c][None]),
            jnp.asarray([done]), table, backend=backend)
        done += c
    assert done == L

    bit_equal = all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(st_a), jax.tree.leaves(st_b)))
    return bit_equal, (int(jnp.argmax(lg_a[0, -1]))
                       == int(jnp.argmax(lg_b[0, -1])))


@pytest.mark.parametrize("L,chunks", [
    (13, [8, 4, 1]),     # not a multiple of chunk (8) or page_size (4)
    (7, [4, 2, 1]),      # not a multiple of page_size
    (8, [8]),            # single full chunk
    (5, [1, 1, 1, 1, 1]),  # chunk=1 degenerates to the old per-token path
])
def test_chunked_prefill_bit_identical_to_scan(params, L, chunks):
    """The tentpole acceptance bar: a chunked prefill leaves EXACTLY the
    cache (INT8 codes via the same per-token bump-rescale, running
    exponents) and greedy next token that L single-token steps leave."""
    bit_equal, greedy_same = _scan_vs_chunk(CFG, params, L, chunks, "oracle")
    assert bit_equal and greedy_same


def test_chunked_prefill_bit_identical_pallas(params):
    from repro.exec import PallasBackend
    bit_equal, greedy_same = _scan_vs_chunk(
        CFG, params, 13, [8, 4, 1], PallasBackend(interpret=True))
    assert bit_equal and greedy_same


def test_chunked_prefill_recurrent_arch_bit_identical():
    """Mixed attn/rwkv/rglru stack: the chunked path must force the exact
    sequential recurrences (rwkv impl="scan", rglru exact_scan) so the
    carried states match the per-token scan bit-for-bit even when the
    config asks for the chunk-parallel WKV."""
    cfg = ModelConfig(name="m", family="dense", n_layers=3, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                      dtype="float32",
                      block_pattern=("attn", "rwkv", "rglru"),
                      d_rnn=32, wkv_impl="chunked", wkv_chunk=4)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    bit_equal, greedy_same = _scan_vs_chunk(cfg, p, 13, [8, 4, 1], "oracle")
    assert bit_equal and greedy_same


def test_engine_chunk1_matches_chunked(params):
    """Whole-engine degeneracy: prefill_chunk=1 (the old token-by-token
    behavior) and a chunked engine produce identical streams."""
    spec = [(i, _prompt(5 + 3 * i, seed=i), 6) for i in range(3)]
    outs = {}
    for chunk in (1, 8):
        eng = _engine(params, max_batch=3, page_size=4, n_pages=32,
                      prefill_chunk=chunk)
        done = eng.run([Request(uid=u, tokens=t, max_new_tokens=n)
                        for u, t, n in spec])
        outs[chunk] = {r.uid: r.out for r in done}
        eng.sched.assert_invariants()
    assert outs[1] == outs[8]


def test_prefill_pauses_at_chunk_boundary_and_resumes(params):
    """A prefill that cannot grow its next chunk's pages (older slot holds
    the pool) pauses WITHOUT preemption — it keeps its slot, pages and
    ``prefilled_len`` — and resumes from the same chunk boundary once the
    older request drains.  Outputs match a roomy engine exactly."""
    a = Request(uid=0, tokens=_prompt(8, seed=1), max_new_tokens=4)
    b = Request(uid=1, tokens=_prompt(12, seed=2), max_new_tokens=4)
    roomy = _single_stream(params, [(0, a.tokens, 4), (1, b.tokens, 4)])

    # decode_horizon=1: the pause needs the older slot to hold the pool
    # across >= 2 heartbeats; a fused horizon drains it in one macro-step
    # (the horizon-shrink path has its own test below).
    eng = _engine(params, max_batch=2, page_size=4, n_pages=5,
                  prefill_chunk=4, decode_horizon=1)
    eng.add_request(Request(uid=0, tokens=a.tokens, max_new_tokens=4))
    eng.add_request(Request(uid=1, tokens=b.tokens, max_new_tokens=4))
    done, paused, snaps = [], False, []
    for _ in range(64):
        done.extend(eng.step())
        eng.sched.assert_invariants()
        snap = (dict(eng._mid_prefill).keys(), eng.pos.copy())
        if snaps:
            prev_mid, prev_pos = snaps[-1]
            for s in eng._mid_prefill:
                if s in prev_mid and eng.pos[s] == prev_pos[s] > 0:
                    paused = True       # same boundary across two steps
        snaps.append(snap)
        if len(done) == 2:
            break
    assert len(done) == 2
    assert paused, "pool never forced a prefill pause"
    assert eng.sched.stats.preempted == 0  # paused, not evicted
    assert {r.uid: r.out for r in done} == roomy


def test_mid_prefill_preemption_replays_exactly(params):
    """Full preemption of a mid-prefill slot (decode eviction picks the
    latest-admitted victim) releases its pages; on re-admission it
    re-prefills from scratch and still matches the roomy engine."""
    spec = [(i, _prompt(10 + i, seed=i), 6) for i in range(4)]
    single = _single_stream(params, spec)
    # decode_horizon=1 keeps decode slow enough that prefill collides
    # with live decode pages (horizon preemption is covered below).
    eng = _engine(params, max_batch=4, page_size=4, n_pages=8,
                  prefill_chunk=4, prefill_token_budget=4,
                  decode_horizon=1)
    done = eng.run([Request(uid=u, tokens=t, max_new_tokens=n)
                    for u, t, n in spec])
    assert eng.sched.stats.preempted > 0, "pool was not small enough"
    assert {r.uid: r.out for r in done} == single
    eng.sched.assert_invariants()
    assert eng.sched.alloc.n_free == 7


def test_local_window_arch_rejected():
    cfg = ModelConfig(name="lw", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      dtype="float32", block_pattern=("local", "attn"),
                      local_window=8)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.raises(NotImplementedError):
        PagedServingEngine(p, cfg, max_batch=1)


# ---------------------------------------------------------------------------
# Fused decode horizon (PR 10): H fused steps == H single steps, bit-exact
# ---------------------------------------------------------------------------

_HORIZON_CFGS = {
    "dense": CFG,
    "moe": ModelConfig(name="hm", family="dense", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=96,
                       dtype="float32", mlp="moe", n_experts=4, top_k=2),
    "recurrent": ModelConfig(name="hr", family="dense", n_layers=3,
                             d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                             vocab=96, dtype="float32",
                             block_pattern=("attn", "rwkv", "rglru"),
                             d_rnn=32),
}


def _ref_single_steps(cfg, p, state, tokens, pos, table, h, *, active,
                      budget, remaining, eos, rng, backend):
    """H UNFUSED ``decode_step_paged`` calls with the engine's host-side
    masking — the de-fused reference ``decode_horizon_paged`` must match
    bit-for-bit (tokens, emitted mask, positions, every state leaf)."""
    from repro.models.model import paged_state_axes
    axes = paged_state_axes(state, cfg.scan_layers)
    act, bud, rem = map(jnp.asarray, (active, budget, remaining))
    toks, ons = [], []
    for _ in range(h):
        on = act & (bud > 0)
        tbl = jnp.where(on[:, None], table, NULL_PAGE)
        lg, st2 = decode_step_paged(p, cfg, state, tokens, pos, tbl,
                                    backend=backend)

        def keep(old, new, ax):
            if ax == -1:
                return new
            m = on.reshape((1,) * ax + (-1,) + (1,) * (new.ndim - ax - 1))
            return jnp.where(m, new, old)

        state = jax.tree.map(keep, state, st2, axes)
        rng, sub = jax.random.split(rng)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        rem = jnp.where(on, rem - 1, rem)
        fin = on & ((nxt == eos) | (rem <= 0))
        tokens = jnp.where(on, jnp.where(fin, 0, nxt), tokens[:, 0])[:, None]
        pos = pos + on.astype(pos.dtype)
        act = act & ~fin
        bud = bud - on.astype(bud.dtype)
        toks.append(nxt)
        ons.append(on)
    return (jnp.stack(toks, 1), jnp.stack(ons, 1), state, pos, rng)


def _horizon_case(cfg, backend, *, h=4, eos=(-1, -1, -1), remaining=(9, 9, 9)):
    """Fused vs unfused horizon on a 3-slot batch (slot 2 rides inert)."""
    from repro.models.model import decode_horizon_paged
    p = init_lm(jax.random.PRNGKey(1), cfg)
    B, P = 3, 4
    state = init_paged_decode_state(cfg, B, page_size=P, n_pages=16)
    table = jnp.asarray([[1, 2, 3], [4, 5, 6], [NULL_PAGE] * 3], jnp.int32)
    pos = jnp.asarray([0, 2, 0], jnp.int32)
    tokens = jnp.asarray([[7], [11], [0]], jnp.int32)
    kw = dict(active=jnp.asarray([True, True, False]),
              budget=jnp.asarray([h, h, 0], jnp.int32),
              remaining=jnp.asarray(remaining, jnp.int32),
              eos=jnp.asarray(eos, jnp.int32), rng=jax.random.PRNGKey(9))
    fused = decode_horizon_paged(p, cfg, state, tokens, pos, table,
                                 horizon=h, backend=backend, **kw)
    ref = _ref_single_steps(cfg, p, state, tokens, pos, table, h,
                            backend=backend, **kw)
    return fused, ref


def _assert_bit_identical(fused, ref):
    f_tok, f_on, f_st, f_pos, f_key = fused
    r_tok, r_on, r_st, r_pos, r_key = ref
    assert jnp.array_equal(f_tok, r_tok), (f_tok, r_tok)
    assert jnp.array_equal(f_on, r_on), (f_on, r_on)
    assert jnp.array_equal(f_pos, r_pos)
    assert jnp.array_equal(f_key, r_key)
    for fl, rl in zip(jax.tree.leaves(f_st), jax.tree.leaves(r_st)):
        assert fl.dtype == rl.dtype and jnp.array_equal(fl, rl)


@pytest.mark.parametrize("arch", sorted(_HORIZON_CFGS))
def test_horizon_fused_bit_identical_oracle(arch):
    """Tokens, emitted masks, positions AND every cache/recurrent state
    leaf (codes, exponents, rnn carries) match H single steps exactly."""
    fused, ref = _horizon_case(_HORIZON_CFGS[arch], "oracle")
    _assert_bit_identical(fused, ref)


@pytest.mark.parametrize("arch", ["dense", "moe"])
def test_horizon_fused_bit_identical_pallas(arch):
    from repro.exec import PallasBackend
    be = PallasBackend(interpret=True)
    fused, ref = _horizon_case(_HORIZON_CFGS[arch], be)
    _assert_bit_identical(fused, ref)


def test_horizon_mid_eos_and_exhaustion_bit_identical():
    """A slot hitting EOS (slot 0) or its last token (slot 1) mid-horizon
    stops emitting, freezes its position, and writes only to the null
    page thereafter — bit-identical to the masked single-step path."""
    cfg = CFG
    # First pass to discover what slot 0 emits at step 1; use it as EOS.
    (tok, _, _, _, _), _ = _horizon_case(cfg, "oracle")
    eos0 = int(tok[0, 1])
    fused, ref = _horizon_case(cfg, "oracle", eos=(eos0, -1, -1),
                               remaining=(9, 2, 9))
    _assert_bit_identical(fused, ref)
    f_on = np.asarray(fused[1])
    assert f_on[0].tolist() == [True, True, False, False]   # stopped at EOS
    assert f_on[1].tolist() == [True, True, False, False]   # out of tokens
    f_pos = np.asarray(fused[3])
    assert f_pos.tolist() == [2, 4, 0]


@pytest.mark.parametrize("h", [1, 2, 8])
def test_engine_horizon_matches_single_stream(params, h):
    """Whole-engine degeneracy sweep: any decode_horizon produces the
    same streams as the single-stream engine (h=1 IS the old path)."""
    spec = [(i, _prompt(4 + 2 * i, seed=i), 5 + i) for i in range(4)]
    single = _single_stream(params, spec)
    eng = _engine(params, max_batch=3, page_size=4, n_pages=32,
                  decode_horizon=h)
    done = eng.run([Request(uid=u, tokens=t, max_new_tokens=n)
                    for u, t, n in spec])
    assert {r.uid: r.out for r in done} == single
    eng.sched.assert_invariants()
    if h == 8:
        assert max(eng.horizon_hist) > 1      # fusion actually engaged


def test_engine_horizon_shrinks_under_near_dry_pool(params):
    """A tight pool shrinks macro-step budgets (grow_span never evicts)
    instead of preempting: outputs still match the roomy engine and some
    macro-steps run with fewer than decode_horizon fused steps."""
    spec = [(i, _prompt(6, seed=i), 10) for i in range(2)]
    single = _single_stream(params, spec)
    eng = _engine(params, max_batch=2, page_size=4, n_pages=7,
                  prefill_chunk=4, decode_horizon=8)
    done = eng.run([Request(uid=u, tokens=t, max_new_tokens=n)
                    for u, t, n in spec])
    assert {r.uid: r.out for r in done} == single
    eng.sched.assert_invariants()
    assert any(k < 8 for k in eng.horizon_hist), eng.horizon_hist


def test_engine_horizon_preemption_between_macro_steps(params):
    """A pool too small for both long decodes preempts the latest slot
    between macro-steps (never mid-scan); the replayed request still
    matches the single-stream outputs and no page leaks."""
    spec = [(0, _prompt(6, seed=3), 24), (1, _prompt(6, seed=4), 24)]
    single = _single_stream(params, spec)
    eng = _engine(params, max_batch=2, page_size=4, n_pages=9,
                  prefill_chunk=4, decode_horizon=8)
    done = []
    while eng.sched.waiting or any(s is not None for s in eng.sched.slots) \
            or not done:
        if not done and not eng.sched.waiting:
            for u, t, n in spec:
                eng.add_request(Request(uid=u, tokens=t, max_new_tokens=n))
        done.extend(eng.step())
        eng.sched.assert_invariants()          # after every macro-step
    assert eng.sched.stats.preempted > 0, "pool was not small enough"
    assert {r.uid: r.out for r in done} == single
    assert eng.sched.alloc.n_free == 8


def test_horizon_one_bit_identical_to_fused_path(params):
    """decode_horizon=1 and the pre-fusion single-step engine semantics
    coincide: dispatch counters show one launch per token."""
    spec = [(0, _prompt(5), 6)]
    eng = _engine(params, max_batch=1, page_size=4, n_pages=16,
                  decode_horizon=1)
    done = eng.run([Request(uid=u, tokens=t, max_new_tokens=n)
                    for u, t, n in spec])
    assert len(done[0].out) == 6
    # 1 token from prefill logits + 5 decode tokens, one launch each.
    assert eng.decode_dispatches == eng.decode_device_steps == 5
    assert set(eng.horizon_hist) == {1}
