"""End-to-end behaviour tests for the paper's system.

The paper's claim structure, reproduced at CPU scale:
  1. W8A8 QAT baseline trains to some loss L_base.
  2. APSQ (INT8 PSUMs) trains to ~L_base (near-lossless, Table I).
  3. gs > 1 recovers accuracy vs gs = 1 (grouping strategy).
  4. The integer deployment kernel agrees with the QAT fake-quant model.
  5. The analytical energy model says APSQ saves 28-87% (IS/WS).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.models.config import ModelConfig
from repro.models.model import forward, init_lm, lm_loss
from repro.optim import OptimConfig, apply_updates, decay_mask, \
    init_opt_state

CFG = ModelConfig(name="sys", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  dtype="float32", scan_layers=False)
DATA = DataConfig(vocab=256, seq_len=64, global_batch=8, seed=3)


def _train(cfg, steps=30, lr=3e-3):
    corpus = SyntheticCorpus(DATA)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    ocfg = OptimConfig(lr=lr, warmup_steps=3, total_steps=steps,
                       weight_decay=0.0)
    state = init_opt_state(params, ocfg)
    mask = decay_mask(params)

    @jax.jit
    def step(params, state, tokens, labels):
        def loss_fn(p):
            return lm_loss(forward(p, cfg, tokens), labels)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = apply_updates(params, g, state, ocfg, mask)
        return params, state, loss

    losses = []
    for s in range(steps):
        b = corpus.batch_at(s)
        params, state, loss = step(params, state,
                                   jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["labels"]))
        losses.append(float(loss))
    return params, losses


def test_qat_apsq_near_lossless_vs_w8a8():
    _, base = _train(CFG.with_quant(QuantConfig.w8a8()))
    _, apsq = _train(CFG.with_quant(QuantConfig.apsq(gs=2, n_p=4)))
    # both learn; APSQ final loss within 15% of W8A8 baseline
    assert base[-1] < base[0]
    assert apsq[-1] < apsq[0]
    assert apsq[-1] < base[-1] * 1.15, (base[-1], apsq[-1])


def test_fp_training_sanity():
    _, fp = _train(CFG)
    assert fp[-1] < fp[0] * 0.9


@pytest.mark.slow
def test_gs_grouping_recovers_accuracy():
    """Table I direction: eval loss(gs=4) <= eval loss(gs=1) on average."""
    corpus = SyntheticCorpus(DATA)
    evals = {}
    for gs in (1, 4):
        cfg = CFG.with_quant(QuantConfig.apsq(gs=gs, n_p=8))
        params, _ = _train(cfg, steps=40)
        tot = 0.0
        for s in (100, 101, 102, 103):
            b = corpus.batch_at(s)
            tot += float(lm_loss(
                forward(params, cfg, jnp.asarray(b["tokens"])),
                jnp.asarray(b["labels"])))
        evals[gs] = tot / 4
    assert evals[4] <= evals[1] * 1.05, evals


def test_energy_model_headline():
    from repro.energy import (AcceleratorConfig, bert_base, model_energy,
                              savings, segformer_b0)
    acc = AcceleratorConfig()
    for layers, lo, hi in ((bert_base(128), 0.25, 0.6),
                           (segformer_b0(), 0.6, 0.97)):
        base = model_energy(layers, acc, "WS", psum_bits=32)
        s = savings(base, model_energy(layers, acc, "WS", psum_bits=8,
                                       gs=2))
        assert lo < s < hi


def test_kernel_agrees_with_fakequant_reference():
    """Deployment path (integer kernel) == QAT fake-quant semantics under
    matched PO2 scales and rounding."""
    from repro.kernels.apsq_matmul import apsq_matmul_int8, choose_exps
    from repro.core import apsq_accumulate_reference
    key = jax.random.PRNGKey(5)
    xq = jax.random.randint(key, (8, 32), -128, 128, jnp.int8)
    wq = jax.random.randint(jax.random.fold_in(key, 1), (32, 16), -128, 128,
                            jnp.int8)
    n_p, gs = 4, 2
    exps = choose_exps(xq, wq, n_p=n_p, gs=gs)
    kern = apsq_matmul_int8(xq, wq, exps, gs=gs, interpret=True)

    # fake-quant reference in float domain, product scale 1.0, PO2 exps:
    kt = 32 // n_p
    tiles = jnp.einsum("bpk,pkn->pbn",
                       xq.astype(jnp.float32).reshape(8, n_p, kt),
                       wq.astype(jnp.float32).reshape(n_p, kt, 16))
    ref = apsq_accumulate_reference(tiles, exps.astype(jnp.float32), gs)
    # same grid; rounding mode differs (round-half-even vs half-up) by at
    # most one LSB of the largest scale per quantization step
    lsb = 2.0 ** float(jnp.max(exps))
    assert float(jnp.max(jnp.abs(kern.astype(jnp.float32) - ref))) <= lsb * 2
