"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``.

Spins up a continuous-batching engine on a (reduced or full) config and
drives a synthetic request stream, reporting per-request outputs and
decode-step throughput.  ``--engine paged`` serves through the paged
INT8 KV cache (``PagedServingEngine``: page-pool scheduler with
mid-decode eviction, attention reads via the ``kv_attention`` exec op
family); the default ``dense`` engine keeps the float reference path.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--engine", choices=("dense", "paged"), default="dense",
                    help="dense float KV slots, or the paged INT8 KV "
                         "cache with the continuous-batching scheduler")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--backend", default="auto",
                    help="exec backend for integer ops: auto|oracle|pallas")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke
    from repro.models.model import init_lm
    from repro.serving import PagedServingEngine, Request, ServingEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encdec:
        raise SystemExit("enc-dec serving requires encoder inputs; use the "
                         "examples/serve.py driver for seamless")
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        size=rng.integers(4, 32)),
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]

    if args.engine == "paged":
        n_pages = args.cache_len // args.page_size * args.max_batch + 1
        engine = PagedServingEngine(params, cfg, max_batch=args.max_batch,
                                    page_size=args.page_size,
                                    n_pages=n_pages, backend=args.backend)
    else:
        engine = ServingEngine(params, cfg, max_batch=args.max_batch,
                               cache_len=args.cache_len,
                               backend=args.backend)
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt[{len(r.tokens)}] -> {r.out}")


if __name__ == "__main__":
    main()
