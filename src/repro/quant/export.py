"""Integer deployment export: QAT params -> INT8 codes + PO2 shift exponents.

``export_quantized`` walks a calibrated params tree and replaces every
quantized linear's float weight + ``QuantState`` with a
``DeployedQuantState``:

  * weight codes via ``po2_quantize_codes`` (INT8 at the per-channel
    power-of-two scale ``2^floor(log2 aw)`` — bit-exact by construction);
  * activation scale snapped to ``2^floor(log2 ax)``;
  * PSUM shift exponents ``e_i = floor(ap_i) - ax_exp - aw_exp`` in
    product-scale units, the exact layout ``kernels/apsq_matmul`` (and its
    jnp oracle ``ref.apsq_matmul_ref``) consumes.

The deployed tree runs through the ordinary model ``forward`` /
``decode_step`` / ``serving.ServingEngine`` — ``models.common.dense``
dispatches on ``DeployedQuantState`` into the true-integer path
(``repro.core.deployed_dense``).  ``snap_params_po2`` returns the matching
fake-quant reference (same tree, ax/aw snapped to the exported PO2 grid):
deployed and snapped-fake outputs agree to within the rounding-mode gap of
the hardware shifter (round-half-up vs round-half-even — at most one LSB
of the largest PSUM scale per quantization step, see
``tests/test_system.py::test_kernel_agrees_with_fakequant_reference``).

Scan-stacked linears (leading ``n_units`` axis) are exported per unit via
``vmap`` and stay scan-compatible.  MoE expert tensors keep their
fake-quant state (per-expert integer export is future work — the shared
``QuantState`` would need per-expert exponent banks).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (
    DeployedQuantState,
    QuantState,
    effective_n_p,
    po2_quantize_codes,
)


def _export_one(w: jax.Array, qp: QuantState):
    """Export a single [K, *out] weight + state.

    Returns ``(DeployedQuantState, n_clamped)`` where n_clamped counts
    PSUM shifts that would have been negative (a PSUM scale finer than
    the product scale; the hardware shifter cannot left-shift-quantize,
    so they are clamped to 0)."""
    spec = qp.spec
    k = w.shape[0]
    w2d = w.reshape(k, -1).astype(jnp.float32)
    log2_aw = jnp.log2(jnp.maximum(qp.aw.astype(jnp.float32), 1e-30))
    w_codes, aw_exp = po2_quantize_codes(w2d, log2_aw, bits=spec.w_bits)
    ax_exp = jnp.floor(
        jnp.log2(jnp.maximum(qp.ax.astype(jnp.float32), 1e-30))
    ).astype(jnp.int32)
    psum_exps = None
    n_clamped = jnp.zeros((), jnp.int32)
    if qp.ap is not None:
        ap_exp = jnp.floor(qp.ap.astype(jnp.float32)).astype(jnp.int32)
        if aw_exp.ndim:  # per-channel weights -> per-(tile, column) shifts
            psum_exps = ap_exp[:, None] - ax_exp - aw_exp[None, :]
        else:
            psum_exps = ap_exp - ax_exp - aw_exp
        n_clamped = jnp.sum(psum_exps < 0).astype(jnp.int32)
        psum_exps = jnp.maximum(psum_exps, 0)
    return DeployedQuantState(
        w_codes=w_codes, ax_exp=ax_exp, aw_exp=aw_exp, psum_exps=psum_exps,
        spec=spec, name=qp.name, out_dims=tuple(w.shape[1:])), n_clamped


def _snap_one(qp: QuantState) -> QuantState:
    """Snap ax/aw to the exported PO2 grid (fake-quant reference view)."""
    aw = jnp.exp2(jnp.floor(
        jnp.log2(jnp.maximum(qp.aw.astype(jnp.float32), 1e-30))))
    ax = jnp.exp2(jnp.floor(
        jnp.log2(jnp.maximum(qp.ax.astype(jnp.float32), 1e-30))))
    return dataclasses.replace(qp, aw=aw, ax=ax)


def _is_stacked(qp: QuantState) -> bool:
    # per-linear ax is a scalar; a leading scan axis makes it 1-D
    return qp.ax.ndim == 1


def export_quantized(params, policy=None):
    """Export every quantized linear to the integer deployment format.

    Walks the params tree for ``{"w": ..., "qp": QuantState}`` subtrees
    and replaces them with ``{"qp": DeployedQuantState}`` (the float
    weight is dropped — the codes + exponents are the deployment
    artifact).  ``policy`` optionally overrides each layer's spec (e.g.
    re-deploying with a different per-layer gs without re-training PSUM
    scales is legal as long as n_p is unchanged).

    Returns ``(deploy_params, report)`` — report maps layer name to
    {k, n, n_p, gs, mode, int8_bytes, clamped_exps}.
    """
    report: dict = {}

    def export_linear(w, qp: QuantState):
        spec = qp.spec
        stacked = _is_stacked(qp)
        if policy is not None:
            override = policy.resolve(qp.name)
            if override is not None and override.enabled:
                if override.psum.mode != "none":
                    if qp.ap is None:
                        raise ValueError(
                            f"{qp.name}: export policy requests psum mode "
                            f"{override.psum.mode!r} but the layer was "
                            f"calibrated without PSUM scales — re-run "
                            f"calibration with that policy first")
                    k = int(w.shape[1] if stacked else w.shape[0])
                    n_p = qp.ap.shape[-1]
                    eff = effective_n_p(k, override.psum.n_p)
                    if eff != n_p:
                        raise ValueError(
                            f"{qp.name}: export policy n_p="
                            f"{override.psum.n_p} (effective {eff} for "
                            f"K={k}) != calibrated n_p={n_p}")
                    override = dataclasses.replace(
                        override,
                        psum=dataclasses.replace(override.psum, n_p=eff))
                qp = dataclasses.replace(qp, spec=override)
                spec = override
        if stacked:
            # vmap over the scan-stacked leading axis; out_dims metadata is
            # set inside _export_one from the per-unit weight shape
            dq, n_clamped = jax.vmap(_export_one, in_axes=(0, 0))(w, qp)
            n_units = int(w.shape[0])
        else:
            dq, n_clamped = _export_one(w, qp)
            n_units = 1
        clamped = int(jnp.sum(n_clamped))
        prev = report.get(qp.name)
        report[qp.name] = {
            "k": int(dq.w_codes.shape[-2]), "n": int(dq.w_codes.shape[-1]),
            "n_units": n_units,
            "mode": spec.psum.mode if spec else "none",
            "gs": spec.psum.gs if spec else None,
            "n_p": spec.psum.n_p if spec else None,
            "int8_bytes": int(dq.w_codes.size),
            "clamped_exps": clamped,
            # unstacked units share pattern-position names; count them
            "count": 1 + (prev["count"] if prev else 0),
        }
        return {"qp": dq}

    def walk(tree):
        if isinstance(tree, dict):
            if "w" in tree and isinstance(tree.get("qp"), QuantState):
                return export_linear(tree["w"], tree["qp"])
            return {k: walk(v) for k, v in tree.items()}
        return tree

    return walk(params), report


def snap_params_po2(params):
    """Fake-quant reference matching the export: same tree, with every
    ``QuantState``'s ax/aw snapped to ``2^floor(log2 .)``.  Running the
    model on this tree reproduces the deployed integer path up to the
    shifter's rounding mode."""
    def walk(tree):
        if isinstance(tree, QuantState):
            return _snap_one(tree)
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        return tree
    return walk(params)
