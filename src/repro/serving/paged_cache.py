"""Paged INT8 KV cache: fixed-size PO2-scaled pages behind a page table.

The cache for one attention layer is a *pool* of fixed-size pages,

    k_pages / v_pages : int8  [n_pages, page_size, Hkv, hd]
    k_exp  / v_exp    : int32 [max_slots, Hkv]

shared by every request slot; a host-side page table (``[max_slots,
pages_per_slot]`` physical page ids, see ``repro.serving.scheduler``) maps
each slot's logical positions onto pool pages.  Page 0 is the reserved
*null page*: unallocated table entries point at it, writes to it are junk
and reads of it are always masked off by the valid length.

Scales are powers of two per (slot, kv-head) — the paper's RAE shifter
argument (§II-B) applied to the cache: dequantization is a shift, and
growing the scale re-quantizes existing codes with an integer
round-half-up right shift (``_shift_codes``), never a float pass.  The
running exponent only ever grows, and it depends only on the slot's own
tokens, so a request's decode is bit-identical regardless of which other
requests share the pool — the property the continuous-batching parity
tests pin down.

The read path dispatches through the ``repro.exec`` backend registry
(``execute_kv_attention``): the gathered page view is exactly the dense
[B, S, Hkv, hd] layout the ``kernels/int8_kv_attention`` flash-decode
kernel consumes, with ``block_s = page_size``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NULL_PAGE = 0
# Fresh-slot exponent: 2^-24 scale.  Any real activation bumps it; codes
# quantized at it are zero for all practical magnitudes.
EXP_FLOOR = -24


def page_span(start: int, end: int, page_size: int) -> range:
    """Page-aligned start positions of every page holding [start, end).

    The host-side page walk shared by prefill's chunk growth and the
    decode-horizon reservation: ``range(align_down(start), end,
    page_size)`` — empty when ``end <= start``."""
    return range(start - start % page_size, end, page_size)


def po2_exponent(x: jax.Array) -> jax.Array:
    """Smallest PO2 exponent whose 127-code range covers ``x``.

    x: [B, S, Hkv, hd] -> int32 [B, Hkv] (reduced over positions + dims).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 3))
    return jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30) / 127.0)).astype(
        jnp.int32)


def quantize_at(x: jax.Array, exp: jax.Array) -> jax.Array:
    """Float [B, S, Hkv, hd] -> int8 codes at the PO2 scale 2^exp[B, Hkv]."""
    scale = jnp.exp2(exp.astype(jnp.float32))[:, None, :, None]
    return jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                    -127, 127).astype(jnp.int8)


def _shift_codes(codes: jax.Array, shift: jax.Array) -> jax.Array:
    """Re-quantize int8 codes to a coarser PO2 scale: round-half-up >> shift.

    codes: [B, n_pages, P, Hkv, hd] int8; shift: [B, Hkv] int32 >= 0.
    Matches the RAE's shift-with-rounding datapath — no float involved.
    """
    sh = shift[:, None, None, :, None]
    c = codes.astype(jnp.int32)
    half = jnp.where(sh > 0, 1 << jnp.maximum(sh - 1, 0), 0)
    return jnp.clip((c + half) >> sh, -127, 127).astype(jnp.int8)


def _bump_token(gathered: jax.Array, exp: jax.Array, x_new: jax.Array,
                pos: jax.Array):
    """One token of the running-exponent recurrence on a gathered view.

    gathered: [B, n_max, P, Hkv, hd] int8 (a slot's pages, gathered);
    exp: [B, Hkv] int32; x_new: [B, 1, Hkv, hd] float; pos: [B] int32.
    Bumps the exponent to cover the new token, re-quantizes existing codes
    with the integer round-half-up shift, writes the new token's codes.
    Both the per-token scan and the chunked writer are iterations of this
    exact step, which is what makes them bit-identical.
    """
    page_size = gathered.shape[2]
    b_idx = jnp.arange(x_new.shape[0])
    new_exp = jnp.maximum(exp, po2_exponent(x_new))
    gathered = _shift_codes(gathered, new_exp - exp)
    codes = quantize_at(x_new, new_exp)            # [B, 1, Hkv, hd]
    gathered = gathered.at[b_idx, pos // page_size,
                           pos % page_size].set(codes[:, 0])
    return gathered, new_exp


def _update_pool(pages: jax.Array, exp: jax.Array, x_new: jax.Array,
                 pos: jax.Array, page_table: jax.Array):
    """Write one token per slot into the paged pool.

    pages: [n_pages, P, Hkv, hd] int8; exp: [B, Hkv] int32 (running);
    x_new: [B, 1, Hkv, hd] float; pos: [B] int32; page_table: [B, n_max].
    Returns (pages', exp', gathered [B, n_max, P, Hkv, hd]) — the gathered
    view already contains the new token, so the attention read reuses it.
    """
    gathered, new_exp = _bump_token(pages[page_table], exp, x_new, pos)
    pages = pages.at[page_table].set(gathered)
    return pages, new_exp, gathered


def _update_pool_chunk(pages: jax.Array, exp: jax.Array, x_new: jax.Array,
                       pos: jax.Array, page_table: jax.Array):
    """Write a [chunk] of tokens per slot with the per-token bump sequence.

    x_new: [B, C, Hkv, hd] float; pos: [B] int32 (position of the chunk's
    FIRST token).  Round-half-up shifts do not compose (shifting by d1
    then d2 is not shifting by d1+d2), so the chunk writer must replay the
    exact per-token ``_bump_token`` recurrence the decode scan runs — the
    pool is gathered once, iterated in registers, scattered once.

    Returns (pages', exp', gathered, exps_seq [C, B, Hkv]) where
    ``exps_seq[t]`` is the running exponent after the chunk's token ``t``
    — the attention path uses it to detect mid-chunk bumps.
    """
    gathered = pages[page_table]

    def step(carry, xs):
        g, e = carry
        xt, t = xs
        g, e = _bump_token(g, e, xt[:, None], pos + t)
        return (g, e), e

    xs = (jnp.moveaxis(x_new, 1, 0), jnp.arange(x_new.shape[1]))
    (gathered, new_exp), exps_seq = jax.lax.scan(step, (gathered, exp), xs)
    pages = pages.at[page_table].set(gathered)
    return pages, new_exp, gathered, exps_seq


def paged_update_and_attend(cache: dict, q: jax.Array, k_new: jax.Array,
                            v_new: jax.Array, pos: jax.Array,
                            page_table: jax.Array, *, backend=None):
    """One decode step against the paged INT8 cache: write, then attend.

    cache: {"k_pages", "v_pages" [n_pages, P, Hkv, hd] int8;
            "k_exp", "v_exp" [B, Hkv] int32}
    q: [B, Hq, hd] float; k_new/v_new: [B, 1, Hkv, hd] (roped already);
    pos: [B] int32 (position being written); page_table: [B, n_max].

    Returns (out [B, Hq, hd], new_cache).  The attention itself runs
    through ``repro.exec.execute_kv_attention`` with ``block_s`` = the
    page size, so the serving read path is the registered op family
    (oracle jnp reference off-TPU, Pallas flash-decode kernel on TPU).
    """
    from repro.exec import execute_kv_attention
    pos = jnp.asarray(pos, jnp.int32)
    k_pages, k_exp, gk = _update_pool(cache["k_pages"], cache["k_exp"],
                                      k_new, pos, page_table)
    v_pages, v_exp, gv = _update_pool(cache["v_pages"], cache["v_exp"],
                                      v_new, pos, page_table)
    b, n_max, page_size = gk.shape[:3]
    k_seq = gk.reshape(b, n_max * page_size, *gk.shape[3:])
    v_seq = gv.reshape(b, n_max * page_size, *gv.shape[3:])
    out = execute_kv_attention(q, k_seq, v_seq, k_exp, v_exp, pos + 1,
                               block_s=page_size, backend=backend)
    return out, {"k_pages": k_pages, "v_pages": v_pages,
                 "k_exp": k_exp, "v_exp": v_exp}


def paged_prefill_chunk_update_and_attend(cache: dict, q: jax.Array,
                                          k_new: jax.Array, v_new: jax.Array,
                                          pos: jax.Array,
                                          page_table: jax.Array, *,
                                          backend=None):
    """One prefill chunk against the paged INT8 cache: write C tokens,
    attend C causal query rows — bit-identical to C iterations of
    ``paged_update_and_attend``.

    q: [B, C, Hq, hd] float; k_new/v_new: [B, C, Hkv, hd] (roped);
    pos: [B] int32 — position of the chunk's FIRST token.

    The cache write replays the per-token bump recurrence exactly
    (``_update_pool_chunk``), so pools and exponents always match the
    scan.  The attention read has two regimes:

    * **stable** (the overwhelmingly common case): the running exponents
      after the chunk's first token already cover the whole chunk — every
      query row then sees the same codes the scan saw, and one chunked
      ``execute_kv_attention`` call with the in-chunk causal mask is
      bit-identical.
    * **mid-chunk bump**: a later token grew an exponent, so the scan's
      earlier rows attended over *finer* codes than the final view holds
      (the round-half-up rescale is lossy).  Fall back to replaying the
      per-row snapshots from the pre-chunk pools — still one fused device
      computation, selected by ``lax.cond`` so the fast path pays nothing.
    """
    from repro.exec import execute_kv_attention
    pos = jnp.asarray(pos, jnp.int32)
    chunk = q.shape[1]
    page_size = cache["k_pages"].shape[1]
    gk0 = cache["k_pages"][page_table]
    gv0 = cache["v_pages"][page_table]
    k_pages, k_exp, gk, k_exps = _update_pool_chunk(
        cache["k_pages"], cache["k_exp"], k_new, pos, page_table)
    v_pages, v_exp, gv, v_exps = _update_pool_chunk(
        cache["v_pages"], cache["v_exp"], v_new, pos, page_table)
    b, n_max = gk.shape[:2]
    seq = n_max * page_size

    def attend_stable(_):
        k_seq = gk.reshape(b, seq, *gk.shape[3:])
        v_seq = gv.reshape(b, seq, *gv.shape[3:])
        return execute_kv_attention(q, k_seq, v_seq, k_exp, v_exp,
                                    pos + chunk, block_s=page_size,
                                    backend=backend)

    def attend_replay(_):
        def step(carry, xs):
            cgk, cke, cgv, cve = carry
            qt, kt, vt, t = xs
            cgk, cke = _bump_token(cgk, cke, kt[:, None], pos + t)
            cgv, cve = _bump_token(cgv, cve, vt[:, None], pos + t)
            out_t = execute_kv_attention(
                qt, cgk.reshape(b, seq, *cgk.shape[3:]),
                cgv.reshape(b, seq, *cgv.shape[3:]), cke, cve,
                pos + t + 1, block_s=page_size, backend=backend)
            return (cgk, cke, cgv, cve), out_t

        xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k_new, 1, 0),
              jnp.moveaxis(v_new, 1, 0), jnp.arange(chunk))
        carry = (gk0, cache["k_exp"], gv0, cache["v_exp"])
        _, outs = jax.lax.scan(step, carry, xs)
        return jnp.moveaxis(outs, 0, 1)            # [B, C, Hq, hd]

    stable = (jnp.all(k_exps[0] == k_exp) & jnp.all(v_exps[0] == v_exp))
    out = jax.lax.cond(stable, attend_stable, attend_replay, None)
    return out, {"k_pages": k_pages, "v_pages": v_pages,
                 "k_exp": k_exp, "v_exp": v_exp}


def paged_cache_bytes(cfg, *, n_pages: int, page_size: int,
                      max_batch: int, cache_len: int) -> dict:
    """Device bytes of the paged INT8 pools vs the dense f32/bf16 caches.

    Counts every full-attention layer ("attn" kind) of ``cfg``; the dense
    baseline is what ``init_decode_state`` allocates per slot.
    """
    n_attn = sum(1 for k in cfg.block_pattern if k == "attn")
    n_attn *= cfg.n_units
    n_attn += sum(1 for k in cfg.block_pattern[:cfg.n_rem] if k == "attn")
    per_tok = cfg.n_kv_heads * cfg.hd * 2          # k and v
    el = jnp.dtype(cfg.dtype).itemsize
    return {
        "int8_paged": n_attn * (n_pages * page_size * per_tok
                                + max_batch * cfg.n_kv_heads * 2 * 4),
        "dense_f32": n_attn * max_batch * cache_len * per_tok * 4,
        "dense_native": n_attn * max_batch * cache_len * per_tok * el,
        "n_attn_layers": n_attn,
    }
