"""Benchmark driver: one section per paper table/figure + system benches.

``PYTHONPATH=src python -m benchmarks.run [--fast]``
Prints ``name,...`` CSV lines; every section maps to a paper artifact
(see DESIGN.md §7) or a beyond-paper extension.
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip QAT training sections (energy-only)")
    ap.add_argument("--steps", type=int, default=60,
                    help="QAT steps per Table-I variant")
    args = ap.parse_args()

    from . import (arch_energy, fig1_breakdown, fig5_precision,
                   fig6_energy_gs, kernel_bench, roofline_table,
                   table2_area_proxy, table4_llama_energy)

    sections = [
        ("fig1 (energy breakdown)", lambda: fig1_breakdown.run()),
        ("fig6 (energy vs gs)", lambda: fig6_energy_gs.run()),
        ("table4 (LLaMA2 energy)", lambda: table4_llama_energy.run()),
        ("table2 (RAE area proxy)", lambda: table2_area_proxy.run()),
        ("arch_energy (10 assigned archs)", lambda: arch_energy.run()),
        ("kernel (Pallas APSQ)", lambda: kernel_bench.run()),
        ("roofline (dry-run aggregate)", lambda: roofline_table.run()),
    ]
    if not args.fast:
        from . import table1_accuracy
        sections.insert(2, ("table1 (QAT accuracy sweep)",
                            lambda: table1_accuracy.run(steps=args.steps)))
        sections.insert(3, ("fig5 (energy+loss vs precision)",
                            lambda: fig5_precision.run(steps=args.steps)))

    for name, fn in sections:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        fn()
        print(f"=== done in {time.time() - t0:.1f}s ===", flush=True)


if __name__ == "__main__":
    main()
