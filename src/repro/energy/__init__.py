"""Analytical accelerator energy model (paper eqs 1-6) + layer walks."""
from .model import (
    HORO,
    AcceleratorConfig,
    EnergyConstants,
    LayerEnergySpec,
    LayerShape,
    access_counts,
    energy_summary,
    layer_energy,
    model_energy,
    savings,
)
from .workloads import (
    arch_layers,
    bert_base,
    efficientvit_b1,
    llama2_7b,
    llama2_7b_autoregressive,
    llama2_7b_combined,
    segformer_b0,
)

__all__ = [
    "HORO", "AcceleratorConfig", "EnergyConstants", "LayerEnergySpec",
    "LayerShape",
    "access_counts", "energy_summary", "layer_energy", "model_energy",
    "savings", "arch_layers", "bert_base", "efficientvit_b1", "llama2_7b",
    "llama2_7b_autoregressive", "llama2_7b_combined", "segformer_b0",
]
