"""The co-exploration loop: candidates -> scores -> Pareto -> mutate.

QUIDAM-style accelerator/model co-exploration specialized to APSQ's
per-layer knobs: each iteration scores every new candidate policy on
(analytical energy, fake-quant accuracy proxy), keeps the Pareto front,
and breeds the next generation by locally mutating front members.  The
search is deterministic (seeded RNG, deduped assignments) and ends with a
servability proof: the front's best-accuracy policy is calibrated,
exported, and executed through the Pallas kernel vs the jnp oracle.

Energy is scored on the *full-size* architecture (the analytical model is
O(#GEMM names), so TinyLlama at seq 4096 costs microseconds); the
accuracy proxy runs the arch's smoke-scale sibling so a full search stays
CPU-minutes.  Both sides resolve the SAME policy against the SAME layer
namespace, which is the point of ``repro.search.inventory``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import time

from repro.configs import get_config, get_smoke
from repro.core import QuantConfig
from repro.energy import AcceleratorConfig
from repro.quant.policy import resolve_quant

from .candidates import (
    Candidate,
    FixedCandidate,
    SearchSpace,
    mutate,
    seed_candidates,
    uniform_baselines,
)
from .evaluate import (
    accuracy_proxy,
    energy_report,
    make_eval_batch,
    oracle_logits,
    roundtrip_report,
)
from .inventory import layer_classes, model_inventory
from .pareto import ScoredCandidate, pareto_front

_NO_QUANT = QuantConfig()     # resolve() fallthrough: psum.mode == "none"


@dataclasses.dataclass(frozen=True)
class SearchBudget:
    """How much exploration one ``run_search`` spends."""

    iterations: int = 3          # mutation rounds after the seed round
    mutations_per_iter: int = 6  # children bred from the front per round
    seq_len: int = 4096          # energy-side sequence length
    stage: str = "prefill"       # energy-side stage (prefill | decode)
    dataflow: str = "WS"         # energy-side dataflow
    eval_batch: int = 2          # accuracy-proxy calibration batch
    eval_seq: int = 32
    seed: int = 0

    @staticmethod
    def smoke() -> "SearchBudget":
        """CI budget: 2 iterations, tiny eval shapes (< ~2 min on CPU)."""
        return SearchBudget(iterations=2, mutations_per_iter=3,
                            eval_batch=1, eval_seq=16)


@dataclasses.dataclass
class SearchResult:
    arch: str
    front: list                  # ScoredCandidate, ascending energy
    scored: list                 # every evaluated ScoredCandidate
    baselines: dict              # name -> ScoredCandidate (uniform anchors)
    roundtrip: dict              # servability proof of the front's best
    # servability proof of the front's best PSUM-quantized policy — the
    # APSQ kernel path itself, in case the best-accuracy member is plain
    # W8A8 (it usually is: least quantization noise)
    roundtrip_psum: dict = dataclasses.field(default_factory=dict)
    budget: SearchBudget = dataclasses.field(default_factory=SearchBudget)
    elapsed_s: float = 0.0

    def report(self) -> dict:
        front_names = {p.candidate.name for p in self.front}
        het_front = [p for p in self.front if p.candidate.heterogeneous]
        base_energies = {n: s.energy_j for n, s in self.baselines.items()}
        dominated = {
            n for n, e in base_energies.items()
            if any(p.energy_j < e for p in het_front)}
        return {
            "arch": self.arch,
            "n_evaluated": len(self.scored),
            "front": [p.report() for p in self.front],
            "n_heterogeneous_on_front": len(het_front),
            "uniform_baselines": {n: s.report()
                                  for n, s in self.baselines.items()},
            "baselines_energy_dominated": sorted(dominated),
            "dominated_points": [p.report() for p in self.scored
                                 if p.candidate.name not in front_names],
            "roundtrip": self.roundtrip,
            "roundtrip_psum": self.roundtrip_psum,
            "budget": dataclasses.asdict(self.budget),
            "elapsed_s": round(self.elapsed_s, 1),
        }

    def save(self, out_dir: str = "experiments/search") -> str:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{self.arch}__pareto.json")
        with open(path, "w") as f:
            json.dump(self.report(), f, indent=1, default=str)
        return path


def run_search(arch: str, budget: SearchBudget | None = None,
               space: SearchSpace | None = None, *,
               acc: AcceleratorConfig | None = None,
               extra_policies: dict | None = None,
               verbose: bool = True) -> SearchResult:
    """Search per-layer (gs, n_p) policies for one architecture.

    ``extra_policies`` ({label: QuantPolicy}) enters hand-written
    policies — e.g. ``repro.quant.policy_presets`` via
    ``evaluate.policy_sweep("all")`` — into the same Pareto plot.
    """
    t0 = time.time()
    budget = budget or SearchBudget()
    space = space or SearchSpace()
    log = print if verbose else (lambda *_: None)

    cfg_full = get_config(arch)
    cfg_eval = get_smoke(arch)
    inventory = model_inventory(cfg_full, budget.seq_len, budget.stage)
    classes = layer_classes(inventory)
    log(f"[search] {arch}: {len(inventory)} GEMMs, "
        f"{len(classes)} layer classes: {sorted(classes)}")

    batch = make_eval_batch(cfg_eval, budget.eval_batch, budget.eval_seq,
                            budget.seed)
    ref = oracle_logits(cfg_eval, batch, budget.seed)

    scored: list = []
    seen: set = set()

    def score(cand) -> ScoredCandidate | None:
        if cand.assignment in seen:
            return None
        seen.add(cand.assignment)
        policy = cand.policy()
        e = energy_report(cfg_full, policy, seq_len=budget.seq_len,
                          stage=budget.stage, dataflow=budget.dataflow,
                          acc=acc, inventory=inventory)
        a = accuracy_proxy(cfg_eval, policy, batch, ref, budget.seed)
        sc = ScoredCandidate(
            candidate=cand, energy_j=e["energy_j"], error=a["error"],
            energy_saving=e["saving"],
            detail={"psum_j": e["psum_j"],
                    "top1_agreement": a["top1_agreement"], "kl": a["kl"]})
        scored.append(sc)
        log(f"[search]   {cand.origin:9s} {cand.name[:64]:64s} "
            f"E={sc.energy_j:.3e}J (save {sc.energy_saving:+.1%}) "
            f"err={sc.error:.4f}")
        return sc

    baselines = {}
    for cand in uniform_baselines(classes, space):
        sc = score(cand)
        if sc is not None:
            baselines[cand.name] = sc
    for cand in seed_candidates(classes, space):
        score(cand)
    for label, policy in (extra_policies or {}).items():
        score(FixedCandidate(name=label, fixed_policy=policy))

    rng = random.Random(budget.seed)
    for it in range(budget.iterations):
        front = pareto_front(scored)
        log(f"[search] iter {it}: front size {len(front)} "
            f"({sum(p.candidate.heterogeneous for p in front)} "
            f"heterogeneous)")
        # fixed presets have no per-class assignment to mutate
        parents = [p for p in front if isinstance(p.candidate, Candidate)]
        if not parents:
            break
        children = 0
        attempts = 0
        while children < budget.mutations_per_iter and attempts < 50:
            attempts += 1
            parent = parents[rng.randrange(len(parents))]
            child = mutate(parent.candidate, rng, space)
            if score(child) is not None:
                children += 1

    front = pareto_front(scored)
    best_acc = min(front, key=lambda p: p.error)
    log(f"[search] final front: {len(front)} points; best-accuracy "
        f"{best_acc.candidate.name!r} -> roundtrip")
    rt = roundtrip_report(cfg_eval, best_acc.candidate.policy(), batch,
                          budget.seed)
    log(f"[search] roundtrip: ok={rt['ok']} decode={rt['decode']}")

    # The best-accuracy member is usually plain W8A8 (least quantization
    # noise), which never touches the APSQ PSUM kernel path — also prove
    # the front's best PSUM-quantized policy serves.
    def has_psum(p):
        policy = p.candidate.policy()
        return any((resolve_quant(policy, n) or _NO_QUANT).psum.mode
                   != "none" for names in classes.values() for n in names)

    rt_psum: dict = {}
    psum_members = [p for p in front if p is not best_acc and has_psum(p)]
    if has_psum(best_acc):
        rt_psum = {"same_as_best_accuracy": True, "ok": rt["ok"]}
    elif psum_members:
        best_psum = min(psum_members, key=lambda p: p.error)
        log(f"[search] best PSUM-quantized front member "
            f"{best_psum.candidate.name!r} -> roundtrip")
        rt_psum = roundtrip_report(cfg_eval, best_psum.candidate.policy(),
                                   batch, budget.seed)
        rt_psum["candidate"] = best_psum.candidate.name
        log(f"[search] psum roundtrip: ok={rt_psum['ok']} "
            f"decode={rt_psum['decode']}")
    return SearchResult(arch=arch, front=front, scored=scored,
                        baselines=baselines, roundtrip=rt,
                        roundtrip_psum=rt_psum, budget=budget,
                        elapsed_s=time.time() - t0)
