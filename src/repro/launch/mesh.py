"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).

  single pod : (16, 16)       axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)    axes ("pod", "data", "model") = 512 chips

The "pod" axis is the DCN (data-center network) dimension; "data" and
"model" are ICI axes within one pod.  Gradient compression and ZeRO-1
moment sharding target "pod" (see repro.dist).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
