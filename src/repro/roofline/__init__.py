"""Roofline: 3-term model from compiled dry-run artifacts (v5e target)."""
from .analysis import (
    COLLECTIVE_OPS,
    HwSpec,
    V5E,
    backend_corrected_terms,
    collective_bytes,
    cost_terms,
    gemm_analytic_us,
    model_flops,
    useful_fraction,
)
from .hlo_cost import analyze_hlo

__all__ = ["COLLECTIVE_OPS", "HwSpec", "V5E", "analyze_hlo",
           "backend_corrected_terms", "collective_bytes", "cost_terms",
           "gemm_analytic_us", "model_flops", "useful_fraction"]
