"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).

  single pod : (16, 16)       axes ("data", "model")   = 256 chips
  multi-pod  : (2, 16, 16)    axes ("pod", "data", "model") = 512 chips

The "pod" axis is the DCN (data-center network) dimension; "data" and
"model" are ICI axes within one pod.  Gradient compression and ZeRO-1
moment sharding target "pod" (see repro.dist).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(shape=None, axes=("data", "model")):
    """Small mesh with production axis names over CPU host devices.

    ``make_smoke_mesh()`` keeps the historical default — ``(1, n)`` over
    every available device — but a requested ``shape``/``axes`` pair is
    honored exactly (using the first ``prod(shape)`` devices), so dist
    tests can run 2/4/8-way and multi-pod smoke shapes like
    ``make_smoke_mesh((2, 2, 2), ("pod", "data", "model"))`` under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    devs = jax.devices()
    if shape is None:
        shape = (1, len(devs))
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} / axes {axes} rank mismatch")
    need = math.prod(shape)
    if need > len(devs):
        raise ValueError(f"mesh {shape} needs {need} devices, "
                         f"have {len(devs)} (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count=N)")
    return jax.make_mesh(shape, axes, devices=devs[:need])
