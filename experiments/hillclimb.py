"""§Perf hillclimb driver: re-lower a cell with knob variations and diff
the three roofline terms.

    PYTHONPATH=src python experiments/hillclimb.py rwkv1

Each named iteration below is one hypothesis -> change -> measure cycle;
results are copied into EXPERIMENTS.md §Perf as they land.
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch.dryrun import run_cell, save_report  # noqa


def show(rep):
    print(f"  -> comp={rep.get('compute_s', 0):.3e} "
          f"mem={rep.get('memory_s', 0):.3e} "
          f"coll={rep.get('collective_s', 0):.3e} "
          f"dom={rep.get('dominant')} "
          f"useful={rep.get('useful_flops_fraction', 0):.2f} "
          f"temp={rep.get('temp_size_in_bytes', 0) / 2**30:.2f}GiB")
    return rep


def measure(name, arch, cell, **kw):
    print(f"[{name}]", {k: v for k, v in kw.items()
                        if k not in ('arch', 'cell')})
    rep = run_cell(arch, cell, verbose=False, **kw)
    if not rep["ok"]:
        print("  FAILED:", rep.get("error"))
    else:
        show(rep)
        save_report(rep)
    return rep
