"""Pure-jnp oracle for INT8-KV decode attention (PO2 scales).

The paper stores PSUMs as INT8 codes with power-of-two scales so that
dequantization is a shift (§II-B).  Applied to the *decode* path, the same
trick halves KV-cache bytes — and the decode roofline is pure HBM
bandwidth (§Roofline: every decode cell is memory-bound), so bytes are
latency.  Codes: int8; scales: 2^e per (batch, kv-head), exponents int32.

    out[b, h*G+g] = softmax_s( q . (k_codes[b,s,h] * 2^ke[b,h]) / sqrt(d) )
                    . (v_codes[b,s,h] * 2^ve[b,h])
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def quantize_kv_po2(x: jax.Array):
    """[B, S, H, hd] float -> (int8 codes, int32 exponents [B, H]).

    Scale = 2^ceil(log2(amax/127)): the smallest power of two whose
    127-code range covers the tensor (per batch x head)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 3))
    exp = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-30) / 127.0)).astype(
        jnp.int32)
    scale = jnp.exp2(exp.astype(jnp.float32))[:, None, :, None]
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, exp


def dequantize_kv_po2(codes: jax.Array, exp: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    scale = jnp.exp2(exp.astype(jnp.float32))[:, None, :, None]
    return codes.astype(jnp.float32) * scale


def int8_kv_attention_ref(
    q: jax.Array,           # [B, Hq, hd] or [B, C, Hq, hd] float
    k_codes: jax.Array,     # [B, S, Hkv, hd] int8
    v_codes: jax.Array,     # [B, S, Hkv, hd] int8
    k_exp: jax.Array,       # [B, Hkv] int32
    v_exp: jax.Array,       # [B, Hkv] int32
    length: jax.Array | int,  # valid cache length (scalar or [B])
) -> jax.Array:
    """Oracle attention over the INT8 cache.

    Decode form (3D q): one query row per batch, attending to the first
    ``length`` cache positions; returns [B, Hq, hd].  Prefill-chunk form
    (4D q): C causal query rows whose LAST row sits at cache position
    ``length - 1`` — row ``t`` sees positions ``< length - C + 1 + t`` —
    returns [B, C, Hq, hd].  C = 1 reduces exactly to the decode form.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, C, Hq, hd = q.shape
    S, Hkv = k_codes.shape[1], k_codes.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    k = dequantize_kv_po2(k_codes, k_exp)
    v = dequantize_kv_po2(v_codes, v_exp)
    qf = q.reshape(B, C, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bchgd,bshd->bchgs", qf, k) * scale
    limit = (jnp.reshape(jnp.asarray(length), (-1, 1)) - C + 1
             + jnp.arange(C)[None])                 # [B, C]
    valid = jnp.arange(S)[None, None] < limit[..., None]
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bchgs,bshd->bchgd", p, v)
    out = out.reshape(B, C, Hq, hd).astype(q.dtype)
    return out[:, 0] if squeeze else out


def fp_attention_ref(q, k, v, length):
    """Full-precision reference (tolerance anchor for the INT8 path)."""
    B, S, Hkv, hd = k.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32)) * scale
    valid = jnp.arange(S)[None] < jnp.reshape(jnp.asarray(length), (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)
