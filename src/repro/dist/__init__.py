"""Distribution utilities: sharding rules, TP/EP serving, gradient compression.

Three layers, one per training/serving concern:

``sharding``
    maps the logical axis names used by every ``*_specs`` tree in
    ``repro.models`` onto concrete mesh axes (with divisibility fallbacks
    and no-axis-reuse), and hosts the version-portable ``shard_map``
    wrapper every manual-collective region in the repo goes through.

``tp``
    tensor/expert-parallel *integer serving*: ``shard_deployed`` places
    exported ``DeployedQuantState`` code banks over the "model" axis by
    Algorithm-1 mode (K by whole PSUM tiles for PSQ/W8A8, N for APSQ's
    sequential chain, the expert axis for MoE banks), and the
    ``sharded_*`` executors combine per-device integer partials with
    INT8-on-the-wire collectives (``wire="fp32"`` is the parity-debug
    fallback).  ``ShardedBackend`` in ``repro.exec`` is the entry point;
    ``wire_report`` prices the collectives analytically from the static
    per-layer plan.

``compress``
    the low-bit (INT8 / packed INT4) cross-pod gradient path the trainer
    uses over the DCN ("pod") axis.
"""
from .sharding import (
    DEFAULT_RULES,
    batch_spec,
    optimizer_spec,
    shard_map,
    spec_for,
    tree_specs,
)
from .compress import (
    compress_tree_psum,
    dequantize_grad,
    pack_int4,
    quantize_grad,
    unpack_int4,
)
from .tp import (
    GemmPlan,
    LayerPlan,
    plan_gemm,
    shard_deployed,
    shard_paged_state,
    wire_report,
)

__all__ = [
    "DEFAULT_RULES", "batch_spec", "optimizer_spec", "shard_map",
    "spec_for", "tree_specs", "compress_tree_psum", "dequantize_grad",
    "quantize_grad", "pack_int4", "unpack_int4", "GemmPlan", "LayerPlan",
    "plan_gemm", "shard_deployed", "shard_paged_state", "wire_report",
]
