"""Quantized linear layers — typed per-layer quantizer state (API v2).

Every model in the zoo funnels its projection GEMMs through ``quant_dense``
so that enabling W8A8 + PSUM quantization (PSQ/APSQ, any group size) is a
pure config change, exactly as the paper integrates APSQ into QAT (§IV-A).

The quantizer state of one linear is a registered-pytree dataclass,
``QuantState``, carrying its learned scales (data) plus its *resolved*
``QuantConfig`` and a stable layer name (static metadata).  Because the
spec travels with the state, ``quant_dense`` needs no global config: a
per-layer ``QuantPolicy`` (``repro.quant.policy``) resolves a different
``gs``/``n_p``/bits per layer at init time and the apply path just follows
the state.  ``QuantState`` supports dict-style reads (``qp["ap"]``,
``"ap" in qp``) for compatibility with the legacy ``{"aw","ax","ap"}``
dicts, which ``quant_dense`` still accepts alongside an explicit config.

Calibration is capture-based and functional: ``quant_dense`` takes an
optional ``tap`` list and appends a ``TapRecord`` (name, inputs, weights,
state) whenever it executes eagerly — no monkey-patching, and
``repro.quant.calibrate_model`` reaches linears inside ``lax.scan`` bodies
by slicing scan-stacked params and running per-unit capture passes.

Fake-quant semantics (QAT): weights/activations through LSQ [10]; PSUMs
through PO2-scale quantizers via Algorithm 1.  Deployment is
``DeployedQuantState`` (INT8 weight codes + PO2 shift exponents, produced
by ``repro.quant.export.export_quantized``), executed here with the
true-integer semantics of ``repro.kernels.apsq_matmul``.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .apsq import apsq_matmul
from .quantizers import (
    init_alpha_from,
    lsq_quantize,
    qrange,
)

PSUM_MODES = ("none", "psq", "apsq")


@dataclasses.dataclass(frozen=True)
class PsumQuantConfig:
    """PSUM handling for the simulated IS/WS accelerator."""

    mode: str = "none"  # none | psq | apsq
    gs: int = 2         # group size (Algorithm 1); psq == apsq with gs>=n_p
    n_p: int = 8        # simulated #PSUM tiles along K (= ceil(C_i/P_ci))
    bits: int = 8

    def __post_init__(self):
        if self.mode not in PSUM_MODES:
            raise ValueError(f"psum mode must be one of {PSUM_MODES}")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """W8A8 fake-quant + optional PSUM quantization."""

    enabled: bool = False
    w_bits: int = 8
    a_bits: int = 8
    per_channel_w: bool = True
    psum: PsumQuantConfig = dataclasses.field(default_factory=PsumQuantConfig)

    @staticmethod
    def w8a8() -> "QuantConfig":
        return QuantConfig(enabled=True)

    @staticmethod
    def apsq(gs: int = 2, n_p: int = 8) -> "QuantConfig":
        return QuantConfig(enabled=True, psum=PsumQuantConfig("apsq", gs=gs, n_p=n_p))

    @staticmethod
    def psq(n_p: int = 8) -> "QuantConfig":
        return QuantConfig(enabled=True, psum=PsumQuantConfig("psq", n_p=n_p))


def effective_n_p(k: int, requested: int) -> int:
    """Largest divisor of K that is <= requested (K-tiling must be exact)."""
    n = max(1, min(requested, k))
    while k % n:
        n -= 1
    return n


# ---------------------------------------------------------------------------
# Typed quantizer state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantState:
    """Quantizer state of one linear: learned scales + resolved spec.

    Data (pytree leaves): ``aw`` (LSQ weight scale, per-channel [N] or
    scalar), ``ax`` (LSQ activation scale, scalar), ``ap`` (PO2 log2
    PSUM scales, [n_p]; None when ``spec.psum.mode == "none"``).
    Static metadata: ``spec`` (the per-layer resolved ``QuantConfig``,
    with ``psum.n_p`` already clamped to a divisor of K) and ``name``
    (the stable layer name used by policies, taps, and export).
    """

    aw: jax.Array
    ax: jax.Array
    ap: jax.Array | None = None
    spec: QuantConfig | None = None
    name: str = ""

    # dict-style reads for legacy ``qp["ap"]`` call sites
    _FIELDS = ("aw", "ax", "ap")

    def __getitem__(self, key):
        if key in self._FIELDS:
            v = getattr(self, key)
            if v is None:
                raise KeyError(key)
            return v
        raise KeyError(key)

    def __contains__(self, key):
        return key in self._FIELDS and getattr(self, key) is not None

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def as_dict(self) -> dict:
        d = {"aw": self.aw, "ax": self.ax}
        if self.ap is not None:
            d["ap"] = self.ap
        return d

    @staticmethod
    def from_dict(d: dict, spec: QuantConfig | None = None,
                  name: str = "") -> "QuantState":
        return QuantState(aw=d["aw"], ax=d["ax"], ap=d.get("ap"),
                          spec=spec, name=name)


jax.tree_util.register_dataclass(
    QuantState, data_fields=("aw", "ax", "ap"), meta_fields=("spec", "name"))


@dataclasses.dataclass(frozen=True)
class DeployedQuantState:
    """Integer deployment view of one linear (output of ``export_quantized``).

    Data: ``w_codes`` (INT8 weight codes [K, N]), ``ax_exp`` (activation
    PO2 exponent, scalar int32), ``aw_exp`` (weight PO2 exponents, [N] or
    scalar int32), ``psum_exps`` (PSUM shift exponents in product-scale
    units, [n_p] or [n_p, N] int32; None for plain W8A8).
    Static: ``spec``, ``name``, ``out_dims`` (original trailing weight
    dims, for the output reshape).

    Executed by ``quant_dense``/``deployed_dense`` with the exact integer
    semantics of ``repro.kernels.apsq_matmul`` (shift-based quant/dequant,
    round-half-up) — scan-stackable like any other param subtree.
    """

    w_codes: jax.Array
    ax_exp: jax.Array
    aw_exp: jax.Array
    psum_exps: jax.Array | None = None
    spec: QuantConfig | None = None
    name: str = ""
    out_dims: tuple = ()


jax.tree_util.register_dataclass(
    DeployedQuantState,
    data_fields=("w_codes", "ax_exp", "aw_exp", "psum_exps"),
    meta_fields=("spec", "name", "out_dims"))


@dataclasses.dataclass
class TapRecord:
    """One captured linear invocation (calibration capture API)."""

    name: str
    x: jax.Array    # [tokens, K] activations as seen by the linear
    w: jax.Array    # [K, N] flattened weight
    qp: "QuantState"


def _spec_of(qp, cfg: QuantConfig | None) -> QuantConfig | None:
    if isinstance(qp, QuantState) and qp.spec is not None:
        return qp.spec
    return cfg


# ---------------------------------------------------------------------------
# Init / calibration
# ---------------------------------------------------------------------------

def quant_params_init(w: jax.Array, cfg: QuantConfig,
                      name: str = "") -> QuantState:
    """Quantizer state for one linear with (flattened) weight [K, N]."""
    k = w.shape[0]
    n = int(w.size // k)
    w2d = w.reshape(k, n)
    if cfg.per_channel_w:
        _, qp = qrange(cfg.w_bits, True)
        aw = 2.0 * jnp.mean(jnp.abs(w2d), axis=0) / math.sqrt(qp) + 1e-12
    else:
        aw = init_alpha_from(w2d, cfg.w_bits)
    ap = None
    spec = cfg
    if cfg.psum.mode != "none":
        n_p = effective_n_p(k, cfg.psum.n_p)
        spec = dataclasses.replace(
            cfg, psum=dataclasses.replace(cfg.psum, n_p=n_p))
        # PSUM scales start at a generic magnitude; ``calibrate_dense``
        # refines them from data (running-accumulation statistics).
        ap = jnp.zeros((n_p,), jnp.float32) + jnp.log2(jnp.asarray(16.0))
    return QuantState(aw=aw, ax=jnp.asarray(1.0, jnp.float32), ap=ap,
                      spec=spec, name=name)


def calibrate_dense(qp, x: jax.Array, w: jax.Array,
                    cfg: QuantConfig | None = None):
    """Refine activation & PSUM scales from a calibration batch.

    PSUM scales are initialized from the *running accumulation* magnitude
    (cumsum over tiles) — the quantity APSQ actually quantizes — so early
    tiles get small scales and late tiles get large ones.  Accepts a
    ``QuantState`` (config taken from its spec) or a legacy dict + config.
    """
    spec = _spec_of(qp, cfg)
    if spec is None:
        raise ValueError("calibrate_dense needs a QuantState with a spec "
                         "or an explicit QuantConfig")
    k = w.shape[0]
    n = int(w.size // k)
    w2d = w.reshape(k, n).astype(jnp.float32)
    x2d = x.reshape(-1, k).astype(jnp.float32)
    ax = init_alpha_from(x2d, spec.a_bits)
    ap = qp.get("ap") if isinstance(qp, (QuantState, dict)) else None
    if ap is not None:
        n_p = ap.shape[-1]
        kt = k // n_p
        tiles = jnp.einsum(
            "bpk,pkn->pbn",
            x2d.reshape(-1, n_p, kt),
            w2d.reshape(n_p, kt, n),
        )
        running = jnp.cumsum(tiles, axis=0)
        _, qpmax = qrange(spec.psum.bits, True)
        mags = 2.0 * jnp.mean(jnp.abs(running), axis=(1, 2)) / math.sqrt(qpmax)
        ap = jnp.log2(jnp.maximum(mags, 1e-6))
    if isinstance(qp, QuantState):
        return dataclasses.replace(qp, ax=ax, ap=ap)
    out = dict(qp)
    out["ax"] = ax
    if ap is not None:
        out["ap"] = ap
    return out


# ---------------------------------------------------------------------------
# Fake-quant (QAT) execution
# ---------------------------------------------------------------------------

def quant_dense(
    x: jax.Array,
    w: jax.Array,
    qp,
    cfg: QuantConfig | None = None,
    *,
    tap: list | None = None,
    backend=None,
) -> jax.Array:
    """``x @ w`` with optional W8A8 fake quant and PSQ/APSQ PSUM handling.

    x: [..., K];  w: [K, ...] (trailing dims flattened to N internally).
    ``qp`` is a ``QuantState`` (spec self-carried), a legacy
    ``{"aw","ax","ap"}`` dict (spec from ``cfg``), or a
    ``DeployedQuantState`` (integer path; ``w`` is ignored).
    ``tap``: optional capture list — when executing eagerly, a
    ``TapRecord`` for this linear is appended (calibration capture API).
    ``backend``: execution backend for the deployed integer path
    (``repro.exec``; name, instance, or None for the ``auto`` default).
    Returns [..., *w.shape[1:]] in x.dtype.
    """
    if isinstance(qp, DeployedQuantState):
        return deployed_dense(x, qp, backend=backend)
    spec = _spec_of(qp, cfg)
    out_shape = x.shape[:-1] + w.shape[1:]
    if spec is None or not spec.enabled or qp is None:
        y = jax.lax.dot_general(
            x, w.reshape(w.shape[0], -1).astype(x.dtype),
            (((x.ndim - 1,), (0,)), ((), ())),
        )
        return y.reshape(out_shape)

    k = w.shape[0]
    w2d = w.reshape(k, -1)
    if (tap is not None and isinstance(qp, QuantState)
            and not isinstance(x, jax.core.Tracer)):
        tap.append(TapRecord(qp.name, x.reshape(-1, k), w2d, qp))
    in_dtype = x.dtype
    xf = x.astype(jnp.float32)
    wf = w2d.astype(jnp.float32)
    xq = lsq_quantize(xf, qp["ax"], bits=spec.a_bits)
    wq = lsq_quantize(wf, qp["aw"], bits=spec.w_bits)

    mode = spec.psum.mode
    if mode == "none":
        y = jax.lax.dot_general(
            xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    else:
        # Gather the FSDP(K)-shard of the weight ONCE before the PSUM tile
        # loop, KEEPING the TP(N) shard: without this every one of the n_p
        # tile GEMMs contracts a data-sharded K slice and all-reduces its
        # partial sums — n_p x the collective bytes of the unquantized
        # GEMM.  Full replication (P(None, None)) was measured and
        # REFUTED — it drags replicated weights/grads through the scan
        # residuals (§Perf it2/it3 on the APSQ cell).
        try:
            wq = jax.lax.with_sharding_constraint(
                wq, jax.sharding.PartitionSpec(None, "model"))
        except (ValueError, RuntimeError):
            pass  # no ambient mesh (unsharded smoke/QAT runs)
        n_p = qp["ap"].shape[0]
        gs = n_p if mode == "psq" else spec.psum.gs
        y = apsq_matmul(xq, wq, qp["ap"], n_p=n_p, gs=gs, bits=spec.psum.bits)
    return y.astype(in_dtype).reshape(out_shape)


# ---------------------------------------------------------------------------
# Integer deployment execution
# ---------------------------------------------------------------------------

def tied_head_weight(table: jax.Array) -> jax.Array:
    """The tied-embedding logits weight: table [V, ...D] -> [D, V] fp32.

    One definition shared by head calibration (``quant.qat``), integer
    export (``quant.export``), and the fake-quant forward
    (``models.model.logits_from_hidden``) — the three views must see the
    identical matrix or the calibrated scales/codes stop matching the
    GEMM actually executed.
    """
    return table.reshape(table.shape[0], -1).T.astype(jnp.float32)


def deployed_dense(x: jax.Array, dq: DeployedQuantState, *,
                   backend=None) -> jax.Array:
    """Integer GEMM on exported codes, semantics of ``kernels/apsq_matmul``.

    Activations are quantized to INT8 at the PO2 scale ``2^ax_exp``; the
    INT32 PSUM tiles follow Algorithm 1 with shift exponents ``psum_exps``
    in product-scale units (per-tile, or per-(tile, column) when weights
    are per-channel); the result is rescaled to float.

    The actual integer GEMM is dispatched through the ``repro.exec``
    backend registry: ``oracle`` (pure jnp, runs under jit/scan/vmap),
    ``pallas`` (the real kernel; interpret mode off-TPU), or ``auto``
    (default: pallas on TPU, oracle elsewhere) — all bit-identical.
    """
    from repro.exec import execute_gemm  # lazy: exec imports kernels

    return execute_gemm(dq, x, backend=backend)
