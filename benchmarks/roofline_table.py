"""Aggregate experiments/dryrun/*.json into the §Roofline markdown table."""
import glob
import json
import os

HW_NOTE = ("v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI; "
           "terms are per-chip seconds from the loop-aware HLO analysis")


def load_reports(out_dir: str = "experiments/dryrun") -> list:
    reports = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            reports.append(json.load(f))
    return reports


def suggestion(rep: dict) -> str:
    dom = rep.get("dominant", "")
    if dom == "memory_s":
        return ("raise arithmetic intensity: larger fused blocks / fewer "
                "boundary copies (microbatch size, attention chunk sizes)")
    if dom == "collective_s":
        return ("cut gathered bytes: re-shard embeddings/weights, overlap "
                "FSDP gathers with compute, INT8 DCN grads")
    if dom == "dcn_s":
        return "compress cross-pod traffic (INT8 grads) or shard over ICI"
    return "increase per-chip work or reduce recompute (remat policy)"


def fmt_row(r: dict) -> str:
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['cell']} | {r['mesh']} | FAIL "
                f"| | | | | {r.get('error', '')[:60]} |")
    uf = r.get("useful_flops_fraction", 0.0)
    tag = r.get("tag") or ""
    variant = f" [{tag}]" if tag else ""
    return ("| {arch}{v} | {cell} | {mesh} | {dom} | {c:.2e} | {m:.2e} | "
            "{k:.2e} | {rf:.2f} | {uf:.2f} |").format(
        arch=r["arch"], v=variant, cell=r["cell"], mesh=r["mesh"],
        dom=r.get("dominant", "?").replace("_s", ""),
        c=r.get("compute_s", 0), m=r.get("memory_s", 0),
        k=r.get("collective_s", 0),
        rf=r.get("roofline_fraction", 0), uf=uf)


def run(print_fn=print, out_dir: str = "experiments/dryrun"):
    reports = [r for r in load_reports(out_dir)]
    if not reports:
        print_fn("roofline,no dry-run reports found; run "
                 "PYTHONPATH=src python -m repro.launch.dryrun first")
        return []
    print_fn(f"roofline,# {HW_NOTE}")
    print_fn("| arch | cell | mesh | bottleneck | compute_s | memory_s | "
             "collective_s | roofline_frac | useful_flops |")
    print_fn("|---|---|---|---|---|---|---|---|---|")
    for r in reports:
        print_fn(fmt_row(r))
    n_ok = sum(r.get("ok", False) for r in reports)
    print_fn(f"roofline,cells_ok={n_ok}/{len(reports)}")
    return reports


if __name__ == "__main__":
    run()
