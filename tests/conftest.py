"""Shared test bootstrap.

Forces 8 CPU host devices (before any jax import) so the dist tests in
``test_dist_tp.py`` can build 2- and 8-way meshes; single-device tests
are unaffected — unsharded computation runs on device 0 as before.
Honors a caller-provided XLA_FLAGS (setdefault, no override).
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
