"""CLI: search per-layer (gs, n_p) policies and print the Pareto front.

    PYTHONPATH=src python -m repro.search.cli --arch tinyllama-1.1b \
        --budget-smoke

Prints every scored candidate, the Pareto front with energy savings vs
the INT32-PSUM baseline, which uniform baselines the heterogeneous front
members beat on energy, and the calibrate -> export -> Pallas round trip
of the front's best-accuracy policy.  The full report lands in
``experiments/search/<arch>__pareto.json``.
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs import ARCH_NAMES, canonical_arch

from .candidates import SearchSpace
from .driver import SearchBudget, run_search


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    help=f"architecture id; one of {ARCH_NAMES} "
                         "(module-style spellings accepted)")
    ap.add_argument("--budget-smoke", action="store_true",
                    help="CI budget: 2 iterations, tiny eval shapes")
    ap.add_argument("--iterations", type=int, default=None,
                    help="mutation rounds (overrides the budget default)")
    ap.add_argument("--seq-len", type=int, default=None,
                    help="energy-side sequence length")
    ap.add_argument("--stage", default=None, choices=("prefill", "decode"),
                    help="energy-side stage")
    ap.add_argument("--dataflow", default=None, choices=("IS", "WS"),
                    help="energy-side dataflow")
    ap.add_argument("--gs", type=int, nargs="+", default=None,
                    help="gs choices of the search space")
    ap.add_argument("--n-p", type=int, nargs="+", default=None,
                    help="n_p choices of the search space")
    ap.add_argument("--include-presets", action="store_true",
                    help="score repro.quant.policy_presets on the same "
                         "Pareto plot (the dryrun --quant-policy sweep)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/search")
    args = ap.parse_args(argv)

    arch = canonical_arch(args.arch)
    budget = SearchBudget.smoke() if args.budget_smoke else SearchBudget()
    overrides = {k: v for k, v in (
        ("iterations", args.iterations), ("seq_len", args.seq_len),
        ("stage", args.stage), ("dataflow", args.dataflow),
        ("seed", args.seed if args.seed else None)) if v is not None}
    if overrides:
        budget = dataclasses.replace(budget, **overrides)
    space = SearchSpace()
    if args.gs or args.n_p:
        space = SearchSpace(
            gs_choices=tuple(args.gs) if args.gs else space.gs_choices,
            n_p_choices=tuple(args.n_p) if args.n_p else space.n_p_choices)

    extra = None
    if args.include_presets:
        from .evaluate import policy_sweep
        extra = dict(policy_sweep("all"))
    result = run_search(arch, budget, space, extra_policies=extra)
    rep = result.report()

    print(f"\n[search] Pareto front for {arch} "
          f"({rep['n_evaluated']} candidates, {rep['elapsed_s']}s):")
    for p in result.front:
        het = "het " if p.candidate.heterogeneous else "uni "
        print(f"  {het} E={p.energy_j:.3e}J (save {p.energy_saving:+.1%}) "
              f"err={p.error:.4f}  {p.candidate.name}")
    print(f"[search] heterogeneous points on front: "
          f"{rep['n_heterogeneous_on_front']}")
    print(f"[search] uniform baselines beaten on energy: "
          f"{rep['baselines_energy_dominated']}")
    print(f"[search] roundtrip ok={rep['roundtrip']['ok']} "
          f"decode={rep['roundtrip'].get('decode')}")
    if rep["roundtrip_psum"]:
        print(f"[search] psum roundtrip ok={rep['roundtrip_psum']['ok']} "
              f"({rep['roundtrip_psum'].get('candidate', 'best-accuracy')})")
    path = result.save(args.out)
    print(f"[search] report -> {path}")
    # Exit gate == the subsystem's acceptance bar: >= 2 non-dominated
    # heterogeneous policies, at least one uniform baseline strictly
    # beaten on energy, and the servability proofs (best-accuracy AND
    # best PSUM-quantized front member) pass with backend parity.
    ok = (rep["n_heterogeneous_on_front"] >= 2
          and len(rep["baselines_energy_dominated"]) >= 1
          and rep["roundtrip"]["ok"]
          and rep["roundtrip_psum"].get("ok", True))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
