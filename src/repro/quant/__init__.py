"""QAT integration: calibration, distillation, gs-sweep harness."""
from .qat import (
    SweepResult,
    calibrate_model,
    distill_loss,
    make_distill_loss_fn,
    quant_variants,
)

__all__ = ["SweepResult", "calibrate_model", "distill_loss",
           "make_distill_loss_fn", "quant_variants"]
