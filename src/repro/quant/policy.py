"""Per-layer quantization policies.

The paper's central observation is that PSUM quantization is a *per-layer,
hardware-aware* property of every GEMM: ``n_p = ceil(C_i / P_ci)`` differs
per layer and the reconfigurable RAE switches ``gs`` per layer (§III-C).
``QuantPolicy`` makes that first-class: an ordered list of
``(layer-name glob -> QuantConfig)`` rules, resolved against the stable
layer names the model zoo assigns to every quantized linear
(``unit.<i>.mix.wq``, ``unit.<i>.ffn.wi``, ``rem.<i>...``,
``encoder.unit.<i>...``).

First matching rule wins; ``default`` handles the fallthrough.  A global
``QuantConfig`` is the trivial one-rule policy (``QuantPolicy.uniform``).
Policies are frozen/hashable so they can live inside ``ModelConfig`` and
jit static arguments.

    policy = QuantPolicy.of(
        ("*.mix.*", QuantConfig.apsq(gs=2, n_p=4)),
        ("*.ffn.*", QuantConfig.apsq(gs=4, n_p=8)),
        default=QuantConfig.w8a8(),
    )
    cfg = get_config("tinyllama-1.1b", quant=policy)
"""
from __future__ import annotations

import dataclasses
import fnmatch

from repro.core import QuantConfig


@dataclasses.dataclass(frozen=True)
class QuantRule:
    """One ``glob -> config`` entry of a policy (first match wins)."""

    pattern: str
    config: QuantConfig


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered per-layer quantizer rules with a default fallthrough.

    ``resolve(name)`` returns the ``QuantConfig`` for a layer name, or
    None when no rule matches and there is no default (layer stays float).
    """

    rules: tuple = ()
    default: QuantConfig | None = None

    def __post_init__(self):
        for r in self.rules:
            if not isinstance(r, QuantRule):
                raise TypeError(f"rules must be QuantRule, got {type(r)}")

    def resolve(self, name: str) -> QuantConfig | None:
        for rule in self.rules:
            if fnmatch.fnmatchcase(name, rule.pattern):
                return rule.config
        return self.default

    @staticmethod
    def uniform(config: QuantConfig) -> "QuantPolicy":
        """The trivial policy: one config for every layer."""
        return QuantPolicy(default=config)

    @staticmethod
    def of(*pairs, default: QuantConfig | None = None) -> "QuantPolicy":
        """Build from ``(pattern, config)`` pairs, in precedence order."""
        return QuantPolicy(
            rules=tuple(QuantRule(p, c) for p, c in pairs), default=default)

    def describe(self, names) -> dict:
        """Resolved config per name (debugging / export reports)."""
        return {n: self.resolve(n) for n in names}


def resolve_quant(quant, name: str) -> QuantConfig | None:
    """Normalize a ``QuantConfig | QuantPolicy | None`` to a per-layer
    config (None when the layer stays unquantized)."""
    if quant is None:
        return None
    if isinstance(quant, QuantConfig):
        return quant if quant.enabled else None
    cfg = quant.resolve(name)
    return cfg if (cfg is not None and cfg.enabled) else None
