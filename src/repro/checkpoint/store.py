"""Sharded checkpoints with manifest, async save, reshard-on-load.

Fault-tolerance posture for 1000+ nodes:
  * every leaf is written as its own ``.npy`` under a step directory with a
    JSON manifest (tree structure, shapes, dtypes, step metadata) — on a
    real cluster each host writes only the shards it owns; here the single
    process writes everything (same layout);
  * writes go to ``<dir>/tmp-<step>`` then atomically ``rename`` to
    ``step-<n>`` so a crash mid-save never corrupts the latest checkpoint;
  * ``save_async`` copies to host memory synchronously (cheap) and writes
    in a background thread, so the train loop is blocked only for the
    device->host transfer, not the filesystem;
  * ``restore`` takes an optional ``shardings`` tree and ``jax.device_put``s
    each leaf with the *current* mesh's sharding — elastic restart onto a
    different pod count reshards transparently;
  * emergency checkpoints: ``install_signal_handler`` saves on SIGTERM
    (preemption) before re-raising.
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading

import jax
import numpy as np

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}" if prefix else str(i)))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def _key_to_fname(key: str) -> str:
    return key.replace(_SEP, "__") + ".npy"


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic checkpoint save; returns the final path."""
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, val in flat.items():
        arr = np.asarray(val)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        np.save(os.path.join(tmp, _key_to_fname(key)), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Device->host copy synchronously; filesystem write off-thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()  # one in flight at a time
        host_tree = jax.tree.map(np.asarray, tree)  # blocks on device only

        def _write():
            save(self.ckpt_dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step-{s:09d}"),
                          ignore_errors=True)


def list_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step-"):
            out.append(int(name.split("-")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int | None = None,
            shardings=None) -> tuple:
    """Load a checkpoint; returns (tree, manifest).

    ``shardings``: optional tree (same structure) of NamedSharding/Sharding;
    each leaf is device_put with it — reshard-on-load for elastic restart.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step-{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_shardings = (_flatten(shardings) if shardings is not None else {})
    flat = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, _key_to_fname(key)))
        # numpy round-trips ml_dtypes (bfloat16/int4) as raw void records;
        # reinterpret through the manifest dtype.
        if str(arr.dtype) != meta["dtype"]:
            import jax.numpy as jnp
            arr = arr.view(jnp.dtype(meta["dtype"]))
        sh = flat_shardings.get(key)
        flat[key] = jax.device_put(arr, sh) if sh is not None else arr
    return _unflatten(flat), manifest


def install_signal_handler(checkpointer: AsyncCheckpointer, get_state):
    """Emergency checkpoint on SIGTERM (preemption notice), then re-raise."""
    def handler(signum, frame):
        step, tree = get_state()
        save(checkpointer.ckpt_dir, step, jax.tree.map(np.asarray, tree),
             {"emergency": True})
        signal.signal(signum, signal.SIG_DFL)
        os.kill(os.getpid(), signum)

    signal.signal(signal.SIGTERM, handler)
