"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``.

Spins up the continuous-batching engine on a (reduced or full) config and
drives a synthetic request stream, reporting per-request outputs and
decode-step throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke
    from repro.models.model import init_lm
    from repro.serving import Request, ServingEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encdec:
        raise SystemExit("enc-dec serving requires encoder inputs; use the "
                         "examples/serve.py driver for seamless")
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        size=rng.integers(4, 32)),
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]

    engine = ServingEngine(params, cfg, max_batch=args.max_batch,
                           cache_len=args.cache_len)
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt[{len(r.tokens)}] -> {r.out}")


if __name__ == "__main__":
    main()
