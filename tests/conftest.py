"""Shared test bootstrap.

Forces 8 CPU host devices (before any jax import) so the dist tests in
``test_dist_tp.py`` can build 2- and 8-way meshes; single-device tests
are unaffected — unsharded computation runs on device 0 as before.
Honors a caller-provided XLA_FLAGS (setdefault, no override).

Also drops jax's compiled-executable caches between test modules: each
compile holds several memory mappings (LLVM JIT code pages), and the
full suite's thousands of compiles otherwise walk the process into the
kernel's ``vm.max_map_count`` ceiling (default 65530), where the next
``mmap`` failure segfaults the XLA compiler mid-run.  Clearing per
module keeps the map count bounded; cross-module recompiles are a few
seconds against a ~30-minute suite.
"""
import os

import pytest

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")


@pytest.fixture(autouse=True, scope="module")
def _bound_jax_jit_cache():
    yield
    import jax

    jax.clear_caches()
