"""APSQ algorithm tests: Algorithm 1 semantics, scan == reference, fused
GEMM == tile path, PSQ limit, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    QuantConfig,
    apsq_accumulate,
    apsq_accumulate_reference,
    apsq_matmul,
    calibrate_dense,
    effective_n_p,
    psq_accumulate,
    quant_dense,
    quant_params_init,
)

@pytest.mark.parametrize("n_p", [1, 2, 3, 4, 5, 8, 9])
@pytest.mark.parametrize("gs", [1, 2, 3, 4])
def test_scan_matches_reference(n_p, gs):
    key = jax.random.PRNGKey(n_p * 10 + gs)
    tiles = jax.random.normal(key, (n_p, 4, 6)) * 20
    las = jnp.linspace(-2, 3, n_p)
    ref = apsq_accumulate_reference(tiles, las, gs)
    out = apsq_accumulate(tiles, las, gs)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-6, atol=1e-5)


@given(st.integers(1, 10), st.integers(1, 6))
def test_scan_matches_reference_property(n_p, gs):
    key = jax.random.PRNGKey(n_p * 100 + gs)
    tiles = jax.random.normal(key, (n_p, 3, 5)) * 15
    las = jax.random.uniform(jax.random.fold_in(key, 1), (n_p,), minval=-2,
                             maxval=4)
    ref = apsq_accumulate_reference(tiles, las, gs)
    out = apsq_accumulate(tiles, las, gs)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-6, atol=1e-5)


def test_psq_equals_apsq_with_full_group():
    key = jax.random.PRNGKey(0)
    tiles = jax.random.normal(key, (6, 4, 4)) * 10
    las = jnp.linspace(-1, 2, 6)
    np.testing.assert_allclose(
        np.asarray(psq_accumulate(tiles, las)),
        np.asarray(apsq_accumulate(tiles, las, gs=6)), atol=1e-5)


def test_apsq_matmul_matches_tile_accumulate():
    """Fused GEMM path == explicit tiles -> accumulate path."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (5, 24))
    w = jax.random.normal(jax.random.fold_in(key, 1), (24, 7))
    n_p, gs = 4, 2
    las = jnp.linspace(0, 2, n_p)
    kt = 24 // n_p
    tiles = jnp.einsum("bpk,pkn->pbn", x.reshape(5, n_p, kt),
                       w.reshape(n_p, kt, 7))
    ref = apsq_accumulate(tiles, las, gs)
    out = apsq_matmul(x, w, las, n_p=n_p, gs=gs)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-5, atol=1e-4)


def test_gradients_flow_through_apsq():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (4, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 8)) * 0.3

    def loss(w, las):
        return jnp.sum(jnp.square(apsq_matmul(x, w, las, n_p=4, gs=2)))

    gw, gl = jax.grad(loss, argnums=(0, 1))(w, jnp.zeros(4))
    assert np.all(np.isfinite(np.asarray(gw)))
    assert np.all(np.isfinite(np.asarray(gl)))
    assert float(jnp.sum(jnp.abs(gl))) > 0  # PSUM scales are learnable


def test_effective_n_p():
    assert effective_n_p(24, 8) == 8
    assert effective_n_p(24, 7) == 6
    assert effective_n_p(7, 8) == 7
    assert effective_n_p(16, 5) == 4


@pytest.mark.parametrize("mode", ["psq", "apsq"])
def test_quant_dense_error_small_after_calibration(mode):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (16, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32)) * 0.1
    cfg = (QuantConfig.apsq(gs=2, n_p=8) if mode == "apsq"
           else QuantConfig.psq(n_p=8))
    qp = calibrate_dense(quant_params_init(w, cfg), x, w, cfg)
    y = quant_dense(x, w, qp, cfg)
    ref = x @ w
    rel = float(jnp.mean(jnp.abs(y - ref)) / jnp.mean(jnp.abs(ref)))
    assert rel < 0.25, rel


def test_grouping_reduces_error_vs_gs1():
    """Paper Table I: larger gs reduces cascaded rounding error (on
    average).  The effect is a fraction of a percent pre-training, so the
    comparison needs a decent sample (8 GEMMs was seed-flaky)."""
    key = jax.random.PRNGKey(4)
    errs = {}
    for gs in (1, 4):
        tot = 0.0
        for i in range(64):
            k = jax.random.fold_in(key, i)
            x = jax.random.normal(k, (8, 64))
            w = jax.random.normal(jax.random.fold_in(k, 1), (64, 16)) * 0.2
            cfg = QuantConfig.apsq(gs=gs, n_p=8)
            qp = calibrate_dense(quant_params_init(w, cfg), x, w, cfg)
            y = quant_dense(x, w, qp, cfg)
            tot += float(jnp.sum(jnp.square(y - x @ w)))
        errs[gs] = tot
    assert errs[4] < errs[1], errs
