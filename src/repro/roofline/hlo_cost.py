"""Loop-aware HLO cost analysis (FLOPs / bytes / collective bytes).

``compiled.cost_analysis()`` counts while-loop bodies ONCE — for a
scan-over-layers program that undercounts FLOPs by ~n_layers x, and it does
not report collective bytes at all.  This module re-derives all three
roofline inputs from the optimized HLO text with loop trip-count
multiplication:

  * computations are parsed into per-instruction (opcode, result shape,
    operand shapes) records (operands resolved through a per-computation
    SSA symbol table);
  * ``dot`` FLOPs use the printed dnums (2 * prod(out) * prod(contracting));
  * bytes-accessed follows XLA's own model: operands + result per
    instruction, fusion internals excluded (a fusion node counts only its
    boundary), data-movement-only ops (bitcast/tuple/gte/parameter)
    excluded;
  * the call graph (fusion ``calls=``, while ``body=``/``condition=``,
    conditional branches, reduce ``to_apply=``) is walked recursively;
    while bodies multiply by ``backend_config.known_trip_count`` (emitted
    by XLA for lax.scan/fori) — fallback 1 with a warning flag;
  * collectives (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) accumulate operand bytes x trip multiplier.

Validated against ``cost_analysis()`` on unrolled programs
(tests/test_roofline.py) and against hand-counted GEMM FLOPs.
"""
from __future__ import annotations

import json
import math
import re

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# data-movement / metadata ops: no flops, no byte accounting of their own
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "custom-call", "rng-bit-generator",
}

_SHAPE_TOKEN = re.compile(
    r"((?:[a-z][a-z0-9]*)\[[0-9,]*\])(?:\{[^}]*\})?")


def _shape_bytes(tok: str) -> int:
    m = re.match(r"([a-z][a-z0-9]*)\[([0-9,]*)\]", tok)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _shape_dims(tok: str) -> list:
    m = re.match(r"[a-z][a-z0-9]*\[([0-9,]*)\]", tok)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",") if d]


def _balanced(s: str, start: int) -> int:
    """Index just past the matching ')' for the '(' at ``start``."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


class Instruction:
    __slots__ = ("name", "opcode", "result", "operands", "attrs", "raw")

    def __init__(self, name, opcode, result, operands, attrs, raw):
        self.name = name
        self.opcode = opcode
        self.result = result      # list of shape tokens (tuple flattened)
        self.operands = operands  # list of operand %names
        self.attrs = attrs        # trailing attribute text
        self.raw = raw


_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")


def _parse_instruction(line: str) -> Instruction | None:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # result type: balanced parens tuple or single shape token
    if rest.startswith("("):
        end = _balanced(rest, 0)
        rtype = rest[:end]
        rest2 = rest[end:].lstrip()
    else:
        sm = _SHAPE_TOKEN.match(rest)
        if not sm:
            return None
        rtype = sm.group(0)
        rest2 = rest[sm.end():].lstrip()
    om = re.match(r"([\w\-]+)\(", rest2)
    if not om:
        return None
    opcode = om.group(1)
    op_end = _balanced(rest2, om.end() - 1)
    op_text = rest2[om.end():op_end - 1]
    operands = re.findall(r"%([\w.\-]+)", op_text)
    attrs = rest2[op_end:]
    result = re.findall(r"(?:[a-z][a-z0-9]*)\[[0-9,]*\]", rtype)
    return Instruction(name, opcode, result, operands, attrs, line)


def _parse_computations(text: str) -> dict:
    """name -> list[Instruction].  Computations start at '%name (..' or
    'ENTRY %name (..' at column 0 and end at a lone '}'."""
    comps: dict = {}
    cur = None
    for line in text.splitlines():
        if not line:
            continue
        hdr = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$", line)
        if hdr:
            cur = hdr.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                comps["__entry__"] = comps[cur]
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            instr = _parse_instruction(line)
            if instr is not None:
                comps[cur].append(instr)
    return comps


def _dot_flops(instr: Instruction, shapes: dict) -> float:
    out_elems = math.prod(_shape_dims(instr.result[0])) if instr.result else 0
    lhs = shapes.get(instr.operands[0]) if instr.operands else None
    ldims = _shape_dims(lhs[0]) if lhs else []
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    contracted = 1
    if cm and ldims:
        for d in cm.group(1).split(","):
            if d:
                contracted *= ldims[int(d)]
    return 2.0 * out_elems * contracted


def _conv_flops(instr: Instruction, shapes: dict) -> float:
    out_elems = math.prod(_shape_dims(instr.result[0])) if instr.result else 0
    rhs = shapes.get(instr.operands[1]) if len(instr.operands) > 1 else None
    kdims = _shape_dims(rhs[0]) if rhs else []
    kernel = math.prod(kdims[:-1]) if kdims else 1  # spatial x in-ch
    return 2.0 * out_elems * kernel


_TRIP_RE = re.compile(r"known_trip_count\D*(\d+)")


def _trip_count(instr: Instruction) -> int:
    m = _TRIP_RE.search(instr.attrs)
    return int(m.group(1)) if m else 1


_CALLED_RE = re.compile(
    r"(?:calls|body|condition|to_apply|branch_computations)=\{?%?"
    r"([\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")


def _called_comps(instr: Instruction) -> list:
    out = []
    for m in re.finditer(r"(calls|body|condition|to_apply)=%([\w.\-]+)",
                         instr.attrs):
        out.append((m.group(1), m.group(2)))
    bm = re.search(r"branch_computations=\{([^}]*)\}", instr.attrs)
    if bm:
        for nm in re.findall(r"%([\w.\-]+)", bm.group(1)):
            out.append(("branch", nm))
    return out


def analyze_hlo(text: str) -> dict:
    """Whole-program FLOPs / bytes / collective bytes with loop trips."""
    comps = _parse_computations(text)
    memo: dict = {}
    warnings: list = []

    def comp_cost(name: str) -> dict:
        if name in memo:
            return memo[name]
        memo[name] = {"flops": 0.0, "bytes": 0.0,
                      "coll": {k: 0.0 for k in COLLECTIVE_OPS},
                      "coll_counts": {k: 0 for k in COLLECTIVE_OPS}}
        instrs = comps.get(name, [])
        shapes = {i.name: i.result for i in instrs}
        total = memo[name]
        for ins in instrs:
            op = ins.opcode
            # own flops
            if op == "dot":
                total["flops"] += _dot_flops(ins, shapes)
            elif op == "convolution":
                total["flops"] += _conv_flops(ins, shapes)
            # own bytes
            if op in ("dynamic-slice", "gather", "slice"):
                # XLA's model: only the sliced/gathered bytes move, not
                # the (possibly giant) source operand.
                total["bytes"] += 2.0 * sum(_shape_bytes(t)
                                            for t in ins.result)
            elif op in ("dynamic-update-slice", "scatter"):
                # read+write of the update region only.
                upd = (shapes.get(ins.operands[1])
                       if len(ins.operands) > 1 else None)
                ub = (sum(_shape_bytes(t) for t in upd)
                      if upd else sum(_shape_bytes(t) for t in ins.result))
                total["bytes"] += 2.0 * ub
            elif op not in _SKIP_BYTES and op not in ("fusion", "call",
                                                      "async-start"):
                b = sum(_shape_bytes(t) for t in ins.result)
                for o in ins.operands:
                    if o in shapes:
                        b += sum(_shape_bytes(t) for t in shapes[o])
                total["bytes"] += b
            # collectives
            if op in COLLECTIVE_OPS:
                cb = 0
                for o in ins.operands:
                    if o in shapes:
                        cb += sum(_shape_bytes(t) for t in shapes[o])
                if cb == 0:
                    cb = sum(_shape_bytes(t) for t in ins.result)
                total["coll"][op] += cb
                total["coll_counts"][op] += 1
            # called computations
            called = _called_comps(ins)
            if not called:
                continue
            if op == "while":
                trip = _trip_count(ins)
                if trip == 1 and "known_trip_count" not in ins.attrs:
                    warnings.append(f"while {ins.name}: unknown trip count")
                for _, cn in called:
                    sub = comp_cost(cn)
                    _acc(total, sub, trip)
            elif op == "conditional":
                branches = [comp_cost(cn) for _, cn in called]
                if branches:
                    # conservative: the most expensive branch
                    best = max(branches, key=lambda c: c["flops"] + c["bytes"])
                    _acc(total, best, 1)
            elif op in ("fusion", "call", "async-start"):
                # bytes: min(boundary, internals) — boundary is right for
                # elementwise fusions (intermediates stay in registers),
                # internals are right when the fusion hides a dynamic-slice
                # of a giant operand (boundary would count the full array).
                boundary = sum(_shape_bytes(t) for t in ins.result)
                for o in ins.operands:
                    if o in shapes:
                        boundary += sum(_shape_bytes(t) for t in shapes[o])
                internal = 0.0
                for _, cn in called:
                    sub = comp_cost(cn)
                    _acc(total, sub, 1, flops_only=True)
                    internal += sub["bytes"]
                total["bytes"] += min(boundary, internal) if internal \
                    else boundary
            elif op in ("reduce", "reduce-window", "scatter", "select-and-scatter",
                        "map", "sort", "reduce-scatter", "all-reduce"):
                pass  # applied per-element; elementwise cost negligible
            else:
                for _, cn in called:
                    _acc(total, comp_cost(cn), 1)
        return total

    def _acc(total, sub, mult, flops_only=False):
        total["flops"] += mult * sub["flops"]
        if not flops_only:
            total["bytes"] += mult * sub["bytes"]
        for k in COLLECTIVE_OPS:
            total["coll"][k] += mult * sub["coll"][k]
            total["coll_counts"][k] += mult * sub["coll_counts"][k]

    entry = comp_cost("__entry__") if "__entry__" in comps else {
        "flops": 0.0, "bytes": 0.0,
        "coll": {k: 0.0 for k in COLLECTIVE_OPS},
        "coll_counts": {k: 0 for k in COLLECTIVE_OPS}}
    coll = dict(entry["coll"])
    coll["total"] = sum(coll.values())
    return {
        "flops": entry["flops"],
        "bytes": entry["bytes"],
        "collectives": coll,
        "collective_counts": entry["coll_counts"],
        "warnings": warnings,
    }


def attribute_hlo(text: str, top: int = 12) -> list:
    """Per-computation attribution of the analyze_hlo totals (§Perf tool).

    Returns [(bytes_contrib, flops_contrib, multiplier, name)] sorted by
    byte contribution.  Control-flow (while/cond) multiplies; fusion-called
    computations are folded into their caller (same rules as analyze_hlo),
    so the rows sum to the analyze_hlo totals.
    """
    comps = _parse_computations(text)
    if "__entry__" not in comps:
        return []
    # entry computation name (alias target)
    entry_name = next(n for n, v in comps.items()
                      if n != "__entry__" and v is comps["__entry__"])

    memo_internal: dict = {}

    def internal_bytes(name):  # fused-computation internals, min-rule free
        if name in memo_internal:
            return memo_internal[name]
        tot = 0.0
        instrs = comps.get(name, [])
        shapes = {i.name: i.result for i in instrs}
        for ins in instrs:
            op = ins.opcode
            if op in ("dynamic-slice", "gather", "slice"):
                tot += 2.0 * sum(_shape_bytes(t) for t in ins.result)
            elif op in ("dynamic-update-slice", "scatter"):
                upd = (shapes.get(ins.operands[1])
                       if len(ins.operands) > 1 else None)
                tot += 2.0 * (sum(_shape_bytes(t) for t in upd) if upd
                              else 0.0)
            elif op in ("fusion", "call"):
                for _, cn in _called_comps(ins):
                    tot += internal_bytes(cn)
            elif op not in _SKIP_BYTES:
                b = sum(_shape_bytes(t) for t in ins.result)
                for o in ins.operands:
                    if o in shapes:
                        b += sum(_shape_bytes(t) for t in shapes[o])
                tot += b
        memo_internal[name] = tot
        return tot

    def own_cost(name):
        """Bytes/flops attributable to this computation itself (fusions
        folded in; control-flow children excluded)."""
        by = fl = 0.0
        instrs = comps.get(name, [])
        shapes = {i.name: i.result for i in instrs}
        for ins in instrs:
            op = ins.opcode
            if op == "dot":
                fl += _dot_flops(ins, shapes)
            elif op == "convolution":
                fl += _conv_flops(ins, shapes)
            if op in ("dynamic-slice", "gather", "slice"):
                by += 2.0 * sum(_shape_bytes(t) for t in ins.result)
            elif op in ("dynamic-update-slice", "scatter"):
                upd = (shapes.get(ins.operands[1])
                       if len(ins.operands) > 1 else None)
                by += 2.0 * (sum(_shape_bytes(t) for t in upd) if upd
                             else 0.0)
            elif op in ("fusion", "call", "async-start"):
                boundary = sum(_shape_bytes(t) for t in ins.result)
                for o in ins.operands:
                    if o in shapes:
                        boundary += sum(_shape_bytes(t) for t in shapes[o])
                internal = sum(internal_bytes(cn)
                               for _, cn in _called_comps(ins))
                by += min(boundary, internal) if internal else boundary
                for _, cn in _called_comps(ins):
                    sub_fl = _comp_flops(cn)
                    fl += sub_fl
            elif op not in _SKIP_BYTES:
                b = sum(_shape_bytes(t) for t in ins.result)
                for o in ins.operands:
                    if o in shapes:
                        b += sum(_shape_bytes(t) for t in shapes[o])
                by += b
        return by, fl

    memo_flops: dict = {}

    def _comp_flops(name):
        if name in memo_flops:
            return memo_flops[name]
        fl = 0.0
        instrs = comps.get(name, [])
        shapes = {i.name: i.result for i in instrs}
        for ins in instrs:
            if ins.opcode == "dot":
                fl += _dot_flops(ins, shapes)
            elif ins.opcode == "convolution":
                fl += _conv_flops(ins, shapes)
            elif ins.opcode in ("fusion", "call"):
                for _, cn in _called_comps(ins):
                    fl += _comp_flops(cn)
        memo_flops[name] = fl
        return fl

    # multipliers via control-flow walk
    mult: dict = {entry_name: 1.0}
    order = [entry_name]
    i = 0
    while i < len(order):
        cur = order[i]
        i += 1
        for ins in comps.get(cur, []):
            if ins.opcode not in ("while", "conditional"):
                continue
            m = _trip_count(ins) if ins.opcode == "while" else 1
            for _, cn in _called_comps(ins):
                mult[cn] = mult.get(cn, 0.0) + mult[cur] * m
                if cn not in order:
                    order.append(cn)

    rows = []
    for name, m in mult.items():
        by, fl = own_cost(name)
        rows.append((by * m, fl * m, m, name))
    rows.sort(reverse=True)
    return rows[:top]
