"""Graceful fallback when the ``hypothesis`` dev extra is not installed.

Property-based tests import ``given``/``settings``/``st`` from here: with
hypothesis present this is a pass-through (with the shared "ci" profile
loaded); without it, ``@given(...)`` turns each property test into a
skipped test and the rest of the module still collects and runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=20, deadline=None)
    settings.load_profile("ci")
except ImportError:  # pragma: no cover - exercised only without the extra
    HAVE_HYPOTHESIS = False

    class _Anything:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Anything()

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -r "
                       "requirements-dev.txt)")(fn)
        return deco

    class settings:  # noqa: N801 - mimics hypothesis.settings
        @staticmethod
        def register_profile(*_a, **_k):
            pass

        @staticmethod
        def load_profile(*_a, **_k):
            pass
