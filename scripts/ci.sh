#!/usr/bin/env bash
# CI entrypoint.
#
#   scripts/ci.sh                 tier-1: full test suite (extra args -> pytest)
#   scripts/ci.sh kernel-backend  interpret-mode kernel-backend job: the
#                                 kernel-vs-oracle parity grid + exec-backend
#                                 + block-autotuner tests + a kernel_bench
#                                 --smoke pass (with the machine-readable
#                                 BENCH_kernel.json so the perf trajectory
#                                 is tracked per run).  The fresh run is
#                                 then gated against the committed
#                                 BENCH_kernel.json throughput floor
#                                 (benchmarks/check_kernel_floor.py), so
#                                 both parity AND launch-geometry perf
#                                 regressions fail fast, in isolation from
#                                 the (slower) tier-1 run.
#   scripts/ci.sh search          policy-search smoke: 2-iteration (gs, n_p)
#                                 co-exploration on the tiny arch; fails
#                                 unless the Pareto front is non-empty with
#                                 a heterogeneous member and the winning
#                                 policy round-trips calibrate -> export ->
#                                 pallas with parity.
#   scripts/ci.sh dist            tensor/expert-parallel serving smoke:
#                                 plan/GEMM/engine parity tests over 2- and
#                                 8-way host-device meshes, then dist_bench
#                                 --smoke — sharded greedy decode gated
#                                 token-identical to single-device under
#                                 BOTH wire modes, and the switchable
#                                 int8-vs-fp32 collective byte ratio gated
#                                 >= 3.5x — before the 1->2->8 scaling
#                                 numbers land in BENCH_dist.json.
#   scripts/ci.sh serve           continuous-batching serving smoke: paged
#                                 INT8 KV cache tests + serving_bench
#                                 --smoke (64 Poisson streams, fused
#                                 decode_horizon=8 macro-steps, plus a
#                                 saturated 128-stream decode-bound
#                                 horizon {1,8} sweep cell).  The bench
#                                 itself gates on decode parity — batched
#                                 == single-stream, oracle == interpret-
#                                 mode pallas, AND fused horizon ==
#                                 per-token heartbeats, token-for-token —
#                                 before reporting tokens/s, prefill
#                                 tokens/s, p50/p99 and the host-overhead
#                                 breakdown into BENCH_serving.json.  The
#                                 fresh run is then gated against the
#                                 committed BENCH_serving.json tokens/s +
#                                 ttft_p50 floors AND its own h8-vs-h1
#                                 sweep ratio (check_serving_floor.py
#                                 --min-horizon-speedup), so a scheduler,
#                                 chunked-prefill, or decode-fusion
#                                 regression fails fast like a
#                                 kernel-geometry one.
#
# Collection regressions (missing modules, import errors) fail the run
# because pytest errors out before running a single test.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt
python -m pip install --quiet "jax>=0.4.30" numpy 2>/dev/null || true

if [[ "${1:-}" == "kernel-backend" ]]; then
    shift
    python -m pytest -q tests/test_kernels.py tests/test_exec.py \
        tests/test_autotune.py "$@"
    # Save the committed floor BEFORE the bench overwrites BENCH_kernel.json.
    floor="$(mktemp)"
    git show HEAD:BENCH_kernel.json > "$floor" 2>/dev/null || floor=""
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.kernel_bench --smoke --json BENCH_kernel.json
    if [[ -n "$floor" ]]; then
        PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
            python -m benchmarks.check_kernel_floor BENCH_kernel.json "$floor"
        rm -f "$floor"
    else
        echo "floor,WARN,no committed BENCH_kernel.json — floor gate skipped"
    fi
elif [[ "${1:-}" == "search" ]]; then
    shift
    python -m pytest -q tests/test_search.py "$@"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.search.cli --arch tinyllama-1.1b --budget-smoke
elif [[ "${1:-}" == "dist" ]]; then
    shift
    python -m pytest -q tests/test_dist_tp.py "$@"
    # dist_bench hard-gates internally (mesh-vs-single parity under both
    # wire modes, switchable byte ratio >= 3.5x) before writing the
    # record; the committed BENCH_dist.json is the tracked trajectory.
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.dist_bench --smoke --json BENCH_dist.json
elif [[ "${1:-}" == "serve" ]]; then
    shift
    python -m pytest -q tests/test_paged_serving.py tests/test_kernels_kv.py "$@"
    # Save the committed floor BEFORE the bench overwrites BENCH_serving.json.
    floor="$(mktemp)"
    git show HEAD:BENCH_serving.json > "$floor" 2>/dev/null || floor=""
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.serving_bench --smoke --decode-horizon 8 \
        --json BENCH_serving.json
    if [[ -n "$floor" ]]; then
        PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
            python -m benchmarks.check_serving_floor BENCH_serving.json \
            "$floor" --min-horizon-speedup 1.05
        rm -f "$floor"
    else
        echo "floor,WARN,no committed BENCH_serving.json — floor gate skipped"
    fi
else
    python -m pytest -x -q "$@"
fi
