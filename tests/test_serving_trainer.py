"""Integration: serving engine correctness + trainer loop with resume."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import DataConfig
from repro.models.config import ModelConfig
from repro.models.model import forward, init_lm
from repro.optim import OptimConfig
from repro.serving import Request, ServingEngine, dequantize_kv, quantize_kv
from repro.train import StragglerWatchdog, TrainConfig, Trainer

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                  dtype="float32")


def _greedy_ref(params, cfg, prompt, n, **kw):
    seq = list(prompt)
    for _ in range(n):
        lg = forward(params, cfg, jnp.asarray(seq)[None], **kw)
        seq.append(int(jnp.argmax(lg[0, -1])))
    return seq[len(prompt):]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-2b",
                                  "rwkv6-3b"])
def test_engine_matches_forward_greedy(arch):
    cfg = get_smoke(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(6) % cfg.vocab
    eng = ServingEngine(params, cfg, max_batch=2, cache_len=64,
                        prefill_chunk=8)
    done = eng.run([Request(uid=0, tokens=prompt, max_new_tokens=5)])
    ref = _greedy_ref(params, cfg, list(prompt), 5)
    assert done[0].out == ref, (done[0].out, ref)


def test_engine_continuous_batching_slots():
    params = init_lm(jax.random.PRNGKey(1), CFG)
    eng = ServingEngine(params, CFG, max_batch=2, cache_len=32,
                        prefill_chunk=8)
    reqs = [Request(uid=i, tokens=np.arange(4 + i) % 128, max_new_tokens=4)
            for i in range(5)]
    done = eng.run(reqs)
    assert sorted(r.uid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 4 for r in done)


def test_engine_varied_prompt_lengths_same_compile_bucket():
    params = init_lm(jax.random.PRNGKey(2), CFG)
    eng = ServingEngine(params, CFG, max_batch=1, cache_len=64,
                        prefill_chunk=16)
    for L in (3, 9, 15):  # all pad to one 16-bucket => one prefill compile
        done = eng.run([Request(uid=L, tokens=np.arange(L) % 128,
                                max_new_tokens=3)])
        ref = _greedy_ref(params, CFG, list(np.arange(L) % 128), 3)
        assert done[0].out == ref


def test_engine_eos_token_stops_stream():
    """A stream with ``eos_token`` stops the moment it generates it,
    even though ``max_new_tokens`` would allow far more."""
    params = init_lm(jax.random.PRNGKey(1), CFG)
    prompt = np.arange(6) % 128
    probe = Request(uid=0, tokens=prompt, max_new_tokens=6)
    ServingEngine(params, CFG, max_batch=1, cache_len=64,
                  prefill_chunk=8).run([probe])
    eos = probe.out[2]
    r = Request(uid=1, tokens=prompt, max_new_tokens=50, eos_token=eos)
    done = ServingEngine(params, CFG, max_batch=1, cache_len=64,
                         prefill_chunk=8).run([r])
    expect = probe.out[:probe.out.index(eos) + 1]  # first occurrence stops
    assert done[0].out == expect and done[0].done
    # eos on the very first (prefill) token frees the slot immediately
    r2 = Request(uid=2, tokens=prompt, max_new_tokens=50,
                 eos_token=probe.out[0])
    eng = ServingEngine(params, CFG, max_batch=1, cache_len=64,
                        prefill_chunk=8)
    done2 = eng.run([r2])
    assert done2[0].out == probe.out[:1]
    assert eng.slots == [None]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "recurrentgemma-2b",
                                  "rwkv6-3b"])
def test_engine_decode_horizon_matches_single_step(arch):
    """Non-paged parity knob: decode_horizon=4 fuses 4 decode steps into
    one scan with a device-resident position vector, and emits exactly
    the single-step (h=1) streams — including mid-horizon EOS stops."""
    cfg = get_smoke(arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    # One engine per horizon, reused for both halves (same shapes, so the
    # EOS half adds no fresh scan compiles).
    engines = {h: ServingEngine(params, cfg, max_batch=2, cache_len=64,
                                prefill_chunk=8, decode_horizon=h)
               for h in (1, 4)}
    reqs = lambda: [Request(uid=i, tokens=np.arange(4 + 3 * i) % cfg.vocab,
                            max_new_tokens=3 + 2 * i) for i in range(3)]
    outs = {h: {r.uid: r.out for r in engines[h].run(reqs())}
            for h in (1, 4)}
    assert outs[1] == outs[4]
    # mid-horizon EOS: pick a token the longest stream emits mid-flight
    eos = outs[1][2][1]
    stop = {h: engines[h].run(
        [Request(uid=9, tokens=np.arange(10) % cfg.vocab,
                 max_new_tokens=40, eos_token=eos)])[0].out
            for h in (1, 4)}
    assert stop[1] == stop[4]


def test_int8_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 4, 8))
    codes, scale = quantize_kv(x)
    back = dequantize_kv(codes, scale, jnp.float32)
    rel = float(jnp.mean(jnp.abs(back - x)) / jnp.mean(jnp.abs(x)))
    assert codes.dtype == jnp.int8 and rel < 0.02


def test_trainer_loss_decreases_and_resumes():
    with tempfile.TemporaryDirectory() as d:
        ocfg = OptimConfig(lr=3e-3, warmup_steps=2, total_steps=30,
                           weight_decay=0.0)
        tcfg = TrainConfig(steps=12, save_every=6, log_every=100,
                           ckpt_dir=d, microbatches=2)
        tr = Trainer(CFG, ocfg, tcfg)
        dc = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=4)
        tr.fit(dc, log=lambda *_: None)
        losses = [m["loss"] for m in tr.metrics_log]
        assert losses[-1] < losses[0]
        # resume continues from step 12
        tr2 = Trainer(CFG, ocfg,
                      TrainConfig(steps=14, save_every=100, log_every=100,
                                  ckpt_dir=d))
        tr2.fit(dc, log=lambda *_: None)
        assert tr2.metrics_log[0]["step"] == 12
        assert len(tr2.metrics_log) == 2


def test_straggler_watchdog():
    w = StragglerWatchdog(factor=2.0)
    for s in range(10):
        assert not w.record(s, 1.0)
    assert w.record(10, 5.0)
    assert w.flagged[0][0] == 10


def test_trainer_with_apsq_quant():
    from repro.core import QuantConfig
    cfg = CFG.with_quant(QuantConfig.apsq(gs=2, n_p=4))
    ocfg = OptimConfig(lr=1e-3, warmup_steps=2, total_steps=10,
                       weight_decay=0.0)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, ocfg, TrainConfig(steps=4, save_every=0,
                                            log_every=100, ckpt_dir=d))
        params, _ = tr.fit(DataConfig(vocab=cfg.vocab, seq_len=16,
                                      global_batch=2),
                           log=lambda *_: None)
    assert all(np.all(np.isfinite(np.asarray(x, np.float32)))
               for x in jax.tree.leaves(params))
