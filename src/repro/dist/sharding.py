"""Logical-axis -> mesh-axis sharding rules.

Every ``init_*`` function in the model zoo has a parallel ``*_specs``
function returning *logical axis names* per param (same tree structure).
This module maps those names onto the production mesh:

  * ``spec_for``       — one leaf: logical axes + shape -> PartitionSpec,
    with divisibility checks and no-axis-reuse (first dim wins);
  * ``tree_specs``     — whole tree, structure-aware (understands the
    ``QuantState`` quantizer pytree from ``repro.core``);
  * ``batch_spec``     — activation batch dim over ("pod","data") with
    divisibility fallback to ("data",) then replication;
  * ``optimizer_spec`` — ZeRO-1: shard the first still-replicated,
    pod-divisible dim of an optimizer moment over the DCN "pod" axis.

Rules are overridable per call (``rules={...}``); candidates are tried in
order and skipped when the mesh lacks the axis, the axis is already used
by an earlier dim, or the dim size is not divisible by the axis size.
"""
from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """Version-portable shard_map.

    ``jax.shard_map`` (with ``check_vma``/``axis_names``) only exists on
    newer jax; older releases ship ``jax.experimental.shard_map.shard_map``
    (with ``check_rep``, and partial-manual expressed as the complementary
    ``auto`` set).  Every shard_map call site in the repo goes through here
    so multi-pod paths work on both.

    ``axis_names``: the axes the body is *manual* over (e.g. {"pod"} for
    DCN gradient compression, leaving "data"/"model" to GSPMD).  None
    means manual over every mesh axis.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, **kw)

# logical name -> ordered candidate mesh-axis groups (each a tuple of axes)
DEFAULT_RULES = {
    "batch": (("pod", "data"), ("data",)),
    "embed": (("data",),),          # FSDP: reduction/K dims over "data"
    "embed_out": (("model",),),
    "ff": (("model",),),            # TP: output/N dims over "model"
    "qheads": (("model",),),
    "kvheads": (("model",),),
    "kvheads_cache": (("model",),),
    "heads": (("model",),),
    "vocab": (("model",),),
    "vocab_in": (("data",),),
    "expert": (("model",),),        # EP
    "rnn": (("model",),),
    "norm": (),
    "layers": (),                   # scan-stacked leading axis: replicated
    "ff_unsharded": (),             # MoE expert N dim (expert axis carries EP)
}


def spec_for(axes: tuple, shape: tuple, mesh, rules: dict | None = None) -> P:
    """PartitionSpec for one param from its logical axes and shape.

    ``axes`` entries are logical names or None (replicated).  Each mesh
    axis is used at most once per spec; a candidate is accepted only when
    its total size divides the dim.
    """
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    used: set = set()
    out = []
    for i, name in enumerate(axes):
        choice = None
        for cand in (merged.get(name, ()) if name is not None else ()):
            cand = tuple(cand) if isinstance(cand, (tuple, list)) else (cand,)
            if any(a not in mesh.axis_names or a in used for a in cand):
                continue
            size = math.prod(mesh.shape[a] for a in cand)
            dim = shape[i] if i < len(shape) else 0
            if dim > 0 and dim % size == 0:
                choice = cand
                break
        if choice:
            used.update(choice)
            out.append(choice if len(choice) > 1 else choice[0])
        else:
            out.append(None)
    return P(*out)


def batch_spec(mesh, batch: int, extra_dims: int = 0) -> P:
    """Spec for a [batch, ...] activation: batch over ("pod","data")."""
    return spec_for(("batch",) + (None,) * extra_dims,
                    (batch,) + (1,) * extra_dims, mesh)


def optimizer_spec(spec: P, shape: tuple, mesh) -> P:
    """ZeRO-1: shard the first replicated pod-divisible dim over "pod"."""
    if "pod" not in mesh.axis_names:
        return spec
    pod = mesh.shape["pod"]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, e in enumerate(entries):
        if e is None and shape[i] % pod == 0 and shape[i] > 1:
            entries[i] = "pod"
            break
    return P(*entries)


def tree_specs(spec_tree, shape_tree, mesh, rules: dict | None = None):
    """Map a logical-spec tree over a shape tree -> PartitionSpec tree.

    The result has the *params* tree structure (so it can be mapped to
    NamedShardings and fed to jit in/out_shardings directly).  Quantizer
    state (``repro.core.QuantState``) in the shape tree is paired with the
    ``{"aw","ax","ap"}`` spec dict produced by ``linear_specs``.

    Exported trees work too: a ``DeployedQuantState`` under ``"qp"`` /
    ``"qp_head"`` / ``"qp_<expert>"`` inherits its *weight codes* spec
    from the sibling float-weight entry the export dropped (``"w"``, the
    embedding ``"table"`` — transposed for the tied head — or the expert
    bank name), with every exponent leaf replicated; spec-tree keys whose
    params were consumed by the export are simply skipped.  For the
    serving-side plan (K by whole PSUM tiles, N for APSQ, expert axis for
    MoE banks) use ``repro.dist.tp.shard_deployed`` instead — this path
    exists so generic spec tooling keeps working on deployed trees.
    """
    from repro.core import DeployedQuantState, QuantState

    def deployed(sp_dict, key, dq):
        qspec = sp_dict.get(key) if isinstance(sp_dict, dict) else None
        if isinstance(qspec, dict) and "w_codes" in qspec:  # explicit form
            waxes = qspec["w_codes"]
        else:
            wkey = ("w" if key == "qp"
                    else "table" if key == "qp_head" else key[3:])
            waxes = sp_dict.get(wkey) if isinstance(sp_dict, dict) else None
            if key == "qp_head" and isinstance(waxes, tuple):
                waxes = tuple(reversed(waxes))  # codes are [d, vocab]
        wspec = (spec_for(waxes, tuple(dq.w_codes.shape), mesh, rules)
                 if isinstance(waxes, tuple) else P())
        return dataclasses.replace(
            dq, w_codes=wspec, ax_exp=P(), aw_exp=P(),
            psum_exps=None if dq.psum_exps is None else P())

    def rec(sp, sh, path):
        if isinstance(sp, tuple):
            return spec_for(sp, tuple(sh.shape), mesh, rules)
        if isinstance(sh, QuantState):
            sub = ({f: getattr(sp, f) for f in ("aw", "ax", "ap")}
                   if isinstance(sp, QuantState) else sp)
            return dataclasses.replace(
                sh,
                aw=rec(sub["aw"], sh.aw, path + ("aw",)),
                ax=rec(sub["ax"], sh.ax, path + ("ax",)),
                ap=(rec(sub.get("ap"), sh.ap, path + ("ap",))
                    if sh.ap is not None else None),
            )
        if isinstance(sp, dict):
            missing = set(sh) - set(sp) if isinstance(sh, dict) else set()
            if missing:
                raise KeyError(f"spec tree missing {sorted(missing)} "
                               f"at {'/'.join(path) or '<root>'}")
            if isinstance(sh, dict):
                # Iterate the PARAMS keys: export drops float banks, so
                # stale spec-tree entries ("w", "wi", ...) are skipped.
                return {k: (deployed(sp, k, v)
                            if isinstance(v, DeployedQuantState)
                            else rec(sp[k], v, path + (k,)))
                        for k, v in sh.items()}
            return {k: rec(v, sh[k], path + (k,)) for k, v in sp.items()}
        if sp is None:
            return None if sh is None else P()
        raise TypeError(f"unsupported spec node {type(sp).__name__} "
                        f"at {'/'.join(path) or '<root>'}")

    return rec(spec_tree, shape_tree, ())
