"""Block-shape autotuner for the APSQ Pallas kernels.

The kernel's launch geometry — ``(block_m, block_n)`` tile sizes and the
exponent-block layout — is a per-shape decision, not a constant: decode
(M=1) wants one grid row with the whole K reduction inlined, prefill wants
the largest tiles VMEM can hold, and MoE expert GEMMs sit in between.
QUIDAM (PAPERS.md) treats exactly these tiling/PE-array parameters as a
searchable axis of the accelerator; this module applies the same idea at
the kernel level.

Three layers, fastest first:

  * ``get_block_config(m, k, n, ...)`` — the hot-path lookup every kernel
    launch goes through.  Never times anything: it consults the on-disk
    cache of tuned winners and falls back to the static heuristic, so
    interpret-mode CI and trace-time resolution stay deterministic.
  * ``heuristic_config`` — the static fallback: shape-class-aware tile
    sizes clamped to a VMEM budget.
  * ``tune`` / ``tune_standard_shapes`` — the measured search.  Runs each
    candidate config eagerly (``block_until_ready`` wall-clock), picks
    the fastest, and persists it in a versioned JSON table keyed by
    ``(shape class, n_p, gs, jax backend)``.  Only ever invoked
    explicitly (``kernel_bench --tune`` or the CLI below) — never from
    inside a jitted trace.

Shape classes
-------------
``decode_m1``  M == 1 — single-token decode, served by the m=1 fast-path
               kernel (one grid row over N, the K reduction unrolled).
``small_m``    1 < M <= 32 — small decode batches.
``prefill``    M > 32 — batched prefill / QAT forward.
``expert``     MoE expert-bank GEMMs (per-expert M = dispatch capacity),
               executed by the fused expert-grid kernel.
``prefill_attn``  chunked-prefill KV attention (``int8_kv_attention``
               with a [chunk] query block): m = chunk rows, k = head dim,
               n = gathered KV sequence; ``block_n`` is the kernel's
               ``block_s`` KV tile (snapped to a divisor of S at launch).

Cache
-----
``$REPRO_AUTOTUNE_CACHE`` or ``~/.cache/repro-apsq/autotune-v1.json``.
The file is versioned (``CACHE_VERSION`` is part of the path) and keyed
by jax backend, so a CPU-tuned table never leaks onto TPU.

CLI::

    PYTHONPATH=src python -m repro.kernels.autotune            # tune all
    PYTHONPATH=src python -m repro.kernels.autotune --show     # table
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax

CACHE_VERSION = 1

SHAPE_CLASSES = ("decode_m1", "small_m", "prefill", "expert",
                 "prefill_attn")

# Exponent-block layouts for the [n_p, N] per-channel export layout:
#   "blocked" — the kernel sees a [n_p, block_n] VMEM slice per (j) tile
#               (re-fetched as j advances; minimal VMEM footprint),
#   "full"    — the whole [n_p, N] table sits in VMEM and the kernel
#               slices its column window dynamically (no re-fetch; costs
#               n_p * N bytes of VMEM — only sensible for modest N).
EXP_LAYOUTS = ("blocked", "full")

# Per-output-tile VMEM budget for choosing blocks (x + w + out + banks).
# Half of a ~16 MB core, leaving headroom for pipelining's double buffers.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One launch geometry for ``apsq_matmul``-family kernels.

    ``block_m == 1`` selects the m=1 fast-path kernel (whole-K, single
    grid row); any other value runs the generic (or expert) grid.
    ``source`` records where the config came from ("heuristic", "tuned",
    "override") so benchmark records can tell tuned runs from defaults.
    """

    block_m: int
    block_n: int
    exp_layout: str = "blocked"
    source: str = "heuristic"

    def as_record(self) -> dict:
        """The benchmark-record view (kernel_bench / serving_bench)."""
        return {"block_m": self.block_m, "block_n": self.block_n,
                "exp_layout": self.exp_layout, "blocks_source": self.source}


def shape_class(m: int, *, expert: bool = False, attn: bool = False) -> str:
    """Bucket a GEMM by its M extent (the serving-relevant axis)."""
    if attn:
        return "prefill_attn"
    if expert:
        return "expert"
    if m == 1:
        return "decode_m1"
    if m <= 32:
        return "small_m"
    return "prefill"


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _fit_block(dim: int, cap: int, mult: int) -> int:
    """Largest useful block for ``dim``: the whole (padded) dim if it is
    below ``cap``, else ``cap``.  Always a multiple of ``mult``."""
    return min(cap, _round_up(max(dim, 1), mult))


def _vmem_bytes(bm: int, bn: int, bk: int, gs: int, n_p: int,
                exp_layout: str, n: int) -> int:
    """Working set of one output tile: x/w blocks, INT32 out, INT8 banks,
    and the exponent block (INT32)."""
    exps = n_p * (n if exp_layout == "full" else bn) * 4
    return bm * bk + bk * bn + 4 * bm * bn + gs * bm * bn + exps


def _clamp_to_budget(bm: int, bn: int, k: int, n_p: int, gs: int,
                     exp_layout: str, n: int) -> tuple[int, int, str]:
    """Shrink (bn first, then bm) until the tile fits the VMEM budget."""
    bk = _round_up(k, n_p) // n_p
    while (_vmem_bytes(bm, bn, bk, gs, n_p, exp_layout, n)
           > VMEM_BUDGET_BYTES):
        if exp_layout == "full":
            exp_layout = "blocked"
        elif bn > 128:
            bn = max(128, bn // 2)
        elif bm > 8:
            bm = max(8, bm // 2)
        else:
            break
    return bm, bn, exp_layout


def heuristic_config(cls: str, m: int, k: int, n: int, *, n_p: int,
                     gs: int) -> BlockConfig:
    """Static per-shape-class fallback — never measures anything.

    decode_m1 runs the fast-path kernel (block_m=1, whole K inlined);
    the other classes take the largest tiles that cover the padded dims
    under the VMEM budget, so small shapes get single-launch grids and
    large ones get MXU-aligned 8/128 multiples.
    """
    if cls == "decode_m1":
        bm, bn = 1, _fit_block(n, 512, 128)
    elif cls == "small_m":
        bm, bn = _fit_block(m, 32, 8), _fit_block(n, 512, 128)
    elif cls == "expert":
        bm, bn = _fit_block(m, 128, 8), _fit_block(n, 256, 128)
    elif cls == "prefill_attn":
        # m = chunk rows (all resident in the q tile), n = KV sequence;
        # block_n is the flash-decode block_s KV tile.
        bm, bn = _fit_block(m, 32, 8), _fit_block(n, 512, 128)
    else:  # prefill
        bm, bn = _fit_block(m, 256, 8), _fit_block(n, 512, 128)
    bm, bn, layout = _clamp_to_budget(bm, bn, k, n_p, gs, "blocked", n)
    return BlockConfig(bm, bn, layout, source="heuristic")


def candidate_configs(cls: str, m: int, k: int, n: int, *, n_p: int,
                      gs: int) -> list[BlockConfig]:
    """The deterministic, VMEM-feasible candidate set for one class.

    decode_m1 pins block_m=1 (the fast path has no other M geometry) and
    the expert class pins the "blocked" exponent layout (the fused expert
    kernel keeps per-expert exponent banks blocked per column tile).
    """
    if cls == "decode_m1":
        bms = [1]
    elif cls == "prefill_attn":
        # The chunk's query rows all sit in one q tile; only the KV tile
        # (block_n -> block_s) is searchable geometry.
        bms = [_fit_block(m, 32, 8)]
    else:
        caps = (8, 32, 64, 128, 256)
        bms = sorted({_fit_block(m, c, 8) for c in caps})
    bns = sorted({_fit_block(n, c, 128) for c in (128, 256, 512)})
    layouts = ("blocked",) if cls in ("expert", "decode_m1", "prefill_attn") \
        else EXP_LAYOUTS
    out = []
    for bm in bms:
        for bn in bns:
            for layout in layouts:
                bk = _round_up(k, n_p) // n_p
                if (_vmem_bytes(bm, bn, bk, gs, n_p, layout, n)
                        <= VMEM_BUDGET_BYTES):
                    out.append(BlockConfig(bm, bn, layout,
                                           source="tuned"))
    return out


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-apsq",
                        f"autotune-v{CACHE_VERSION}.json")


def cache_key(cls: str, n_p: int, gs: int, backend: str | None = None) -> str:
    backend = backend or jax.default_backend()
    return f"{cls}|np={n_p}|gs={gs}|{backend}"


_CACHE_MEM: dict[str, dict] = {}


def _load_cache(path: str | None = None, *, refresh: bool = False) -> dict:
    path = path or cache_path()
    if refresh or path not in _CACHE_MEM:
        try:
            with open(path) as f:
                payload = json.load(f)
            entries = payload.get("entries", {}) \
                if payload.get("version") == CACHE_VERSION else {}
        except (OSError, ValueError):
            entries = {}
        _CACHE_MEM[path] = entries
    return _CACHE_MEM[path]


def _store_cache(entries: dict, path: str | None = None) -> None:
    path = path or cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"version": CACHE_VERSION, "entries": entries}, f,
                  indent=1, sort_keys=True)
    os.replace(tmp, path)
    _CACHE_MEM[path] = entries


def clear_memory_cache() -> None:
    """Drop the in-process view of the on-disk table (tests: cold reload)."""
    _CACHE_MEM.clear()


def get_block_config(m: int, k: int, n: int, *, n_p: int, gs: int,
                     expert: bool = False, attn: bool = False,
                     path: str | None = None) -> BlockConfig:
    """The launch-time lookup: cached winner if tuned, else heuristic.

    Pure and timing-free — safe to call at trace time (ops.py calls it
    whenever ``block_m``/``block_n`` are left as None).  The cached entry
    is clamped to the actual padded dims so a winner tuned at a large
    representative shape stays legal on a smaller same-class shape.
    """
    cls = shape_class(m, expert=expert, attn=attn)
    entry = _load_cache(path).get(cache_key(cls, n_p, gs))
    if entry is not None:
        bm = min(int(entry["block_m"]), _round_up(m, 8)) \
            if entry["block_m"] > 1 else 1
        bn = min(int(entry["block_n"]), _round_up(n, 128))
        return BlockConfig(bm, bn, str(entry.get("exp_layout", "blocked")),
                           source="tuned")
    return heuristic_config(cls, m, k, n, n_p=n_p, gs=gs)


# ---------------------------------------------------------------------------
# Measured tuning (explicit, eager — never runs from a trace)
# ---------------------------------------------------------------------------

def _default_measure(cfg: BlockConfig, m: int, k: int, n: int, *, n_p: int,
                     gs: int, expert: bool, reps: int,
                     interpret: bool | None) -> float:
    """Wall-clock one config (jit + warmup + ``block_until_ready``), us."""
    import jax.numpy as jnp

    from .apsq_matmul import (apsq_expert_matmul_int8, apsq_matmul_int8,
                              choose_exps)

    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (m, k), -128, 128, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -128, 128,
                           jnp.int8)
    base = choose_exps(x, w, n_p=n_p, gs=gs)
    # Per-column exponents so the exp_layout axis is actually exercised.
    exps = base[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :] % 2
    if expert:
        E = 4
        xe = jnp.broadcast_to(x, (E, m, k))
        we = jnp.broadcast_to(w, (E, k, n))
        ee = jnp.broadcast_to(exps, (E,) + exps.shape)
        f = lambda: apsq_expert_matmul_int8(
            xe, we, ee, gs=gs, block_m=cfg.block_m, block_n=cfg.block_n,
            interpret=interpret)
    else:
        f = lambda: apsq_matmul_int8(
            x, w, exps, gs=gs, block_m=cfg.block_m, block_n=cfg.block_n,
            exp_layout=cfg.exp_layout, interpret=interpret)
    jax.block_until_ready(f())  # compile + warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _default_measure_attn(cfg: BlockConfig, m: int, k: int, n: int, *,
                          n_p: int, gs: int, expert: bool, reps: int,
                          interpret: bool | None) -> float:
    """Wall-clock one chunked KV-attention launch: m = chunk query rows,
    k = head dim, n = KV sequence; ``cfg.block_n`` is the requested
    ``block_s`` KV tile (snapped to a divisor of S, as at serving time)."""
    import jax.numpy as jnp

    from .int8_kv_attention import int8_kv_attention

    B, Hkv, G = 1, 4, 2
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, m, Hkv * G, k), jnp.float32)
    kc = jax.random.randint(jax.random.fold_in(key, 1), (B, n, Hkv, k),
                            -128, 128, jnp.int8)
    vc = jax.random.randint(jax.random.fold_in(key, 2), (B, n, Hkv, k),
                            -128, 128, jnp.int8)
    exps = jnp.full((B, Hkv), -7, jnp.int32)
    block_s = max(1, min(cfg.block_n, n))
    while n % block_s:
        block_s -= 1
    f = lambda: int8_kv_attention(q, kc, vc, exps, exps, n,
                                  block_s=block_s, interpret=interpret)
    jax.block_until_ready(f())  # compile + warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def tune(m: int, k: int, n: int, *, n_p: int, gs: int,
         expert: bool = False, attn: bool = False, reps: int = 3,
         path: str | None = None,
         interpret: bool | None = None, measure=None,
         verbose=None) -> BlockConfig:
    """Measure every candidate for this shape's class and cache the winner.

    ``measure(cfg, m, k, n, n_p=..., gs=..., expert=..., reps=...,
    interpret=...) -> us`` is injectable (tests use a deterministic fake).
    Ties and near-ties resolve to the earliest candidate in the sorted,
    deterministic candidate order, so the same measurements always yield
    the same winner.
    """
    cls = shape_class(m, expert=expert, attn=attn)
    measure = measure or (_default_measure_attn if attn
                          else _default_measure)
    best_cfg, best_us = None, float("inf")
    for cfg in candidate_configs(cls, m, k, n, n_p=n_p, gs=gs):
        us = measure(cfg, m, k, n, n_p=n_p, gs=gs, expert=expert,
                     reps=reps, interpret=interpret)
        if verbose:
            verbose(f"autotune,{cls},bm={cfg.block_m},bn={cfg.block_n},"
                    f"{cfg.exp_layout},{us:.0f}us")
        if us < best_us:
            best_cfg, best_us = cfg, us
    assert best_cfg is not None, "no feasible candidate config"
    entries = dict(_load_cache(path))
    entries[cache_key(cls, n_p, gs)] = {
        "block_m": best_cfg.block_m, "block_n": best_cfg.block_n,
        "exp_layout": best_cfg.exp_layout, "us": round(best_us, 1),
        "m": m, "k": k, "n": n,
    }
    _store_cache(entries, path)
    return best_cfg


# Representative shapes per class for whole-table tuning: the serving
# shapes kernel_bench tracks (decode/prefill at tinyllama-ish dims) and a
# capacity-sized expert GEMM.
STANDARD_SHAPES = (
    ("decode_m1", dict(m=1, k=1024, n=512, expert=False)),
    ("small_m", dict(m=16, k=1024, n=512, expert=False)),
    ("prefill", dict(m=256, k=1024, n=512, expert=False)),
    ("expert", dict(m=64, k=512, n=256, expert=True)),
    # Chunked-prefill KV attention: m = chunk rows, k = head dim,
    # n = gathered KV sequence.
    ("prefill_attn", dict(m=16, k=64, n=512, expert=False, attn=True)),
)


def tune_standard_shapes(*, n_p: int = 8, gs: int = 2, reps: int = 3,
                         path: str | None = None,
                         interpret: bool | None = None, measure=None,
                         verbose=None) -> dict[str, BlockConfig]:
    """Tune every shape class at its representative shape; returns winners."""
    out = {}
    for cls, shp in STANDARD_SHAPES:
        out[cls] = tune(shp["m"], shp["k"], shp["n"], n_p=n_p, gs=gs,
                        expert=shp["expert"], attn=shp.get("attn", False),
                        reps=reps, path=path,
                        interpret=interpret, measure=measure,
                        verbose=verbose)
    return out


def resolved_table(*, n_p: int = 8, gs: int = 2,
                   shapes=STANDARD_SHAPES) -> dict[str, dict]:
    """What ``get_block_config`` currently resolves per shape class —
    benchmark records embed this so tuned vs default runs are
    distinguishable in the checked-in BENCH JSONs."""
    out = {}
    for cls, shp in shapes:
        cfg = get_block_config(shp["m"], shp["k"], shp["n"], n_p=n_p,
                               gs=gs, expert=shp["expert"],
                               attn=shp.get("attn", False))
        out[cls] = cfg.as_record()
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--show", action="store_true",
                    help="print the resolved table without tuning")
    ap.add_argument("--n-p", type=int, default=8)
    ap.add_argument("--gs", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--cache", default=None,
                    help="cache file (default: REPRO_AUTOTUNE_CACHE or "
                         "~/.cache/repro-apsq/)")
    args = ap.parse_args(argv)
    if args.show:
        for cls, rec in resolved_table(n_p=args.n_p, gs=args.gs).items():
            print(f"{cls:10s} {rec}")
        return 0
    winners = tune_standard_shapes(n_p=args.n_p, gs=args.gs,
                                   reps=args.reps, path=args.cache,
                                   verbose=print)
    for cls, cfg in winners.items():
        print(f"{cls:10s} -> block_m={cfg.block_m} block_n={cfg.block_n} "
              f"exp_layout={cfg.exp_layout}")
    print(f"cached -> {args.cache or cache_path()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
