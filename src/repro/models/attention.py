"""Attention: GQA with chunked (flash-style) softmax, local windows, decode.

Design notes (TPU adaptation):
  * Training/prefill attention is double-chunked — an outer loop over query
    chunks and an inner ``lax.scan`` over KV chunks carrying the online
    softmax state (m, l, acc).  Nothing O(S^2) is ever materialized, which
    is what makes the ``prefill_32k`` cells lowerable.
  * Causally-dead KV chunks are skipped with ``lax.cond`` so the compiled
    HLO does not pay 2x FLOPs for the causal mask (§Perf iteration 1).
  * Local (sliding-window) attention slices just the live window per query
    chunk instead of scanning all KV — RecurrentGemma's 1:2 pattern.
  * Decode reads the whole cache (memory-bound by design); local decode
    uses a ring buffer of ``window`` slots so ``long_500k`` stays O(window).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import QuantConfig
from .common import (
    Params,
    act_spec,
    dense,
    init_linear,
    linear_specs,
    apply_rope,
    shard_hint,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, quant=None,
                   out_dim: int | None = None, name: str = "") -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    out_dim = out_dim or d_model
    return {
        "wq": init_linear(kq, (d_model, n_heads * head_dim), dtype,
                          quant=quant, name=f"{name}.wq"),
        "wk": init_linear(kk, (d_model, n_kv_heads * head_dim), dtype,
                          quant=quant, name=f"{name}.wk"),
        "wv": init_linear(kv, (d_model, n_kv_heads * head_dim), dtype,
                          quant=quant, name=f"{name}.wv"),
        "wo": init_linear(ko, (n_heads * head_dim, out_dim), dtype,
                          quant=quant, name=f"{name}.wo"),
    }


def attention_specs(quant=None, name: str = "") -> Params:
    return {
        "wq": linear_specs(("embed", "qheads"), quant, f"{name}.wq"),
        "wk": linear_specs(("embed", "kvheads"), quant, f"{name}.wk"),
        "wv": linear_specs(("embed", "kvheads"), quant, f"{name}.wv"),
        "wo": linear_specs(("qheads", "embed"), quant, f"{name}.wo"),
    }


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention core
# ---------------------------------------------------------------------------

def _chunk_attend(q, k, v, qpos, kpos, *, causal, window, scale, softcap):
    """Attend one q chunk to one kv chunk; returns (scores_max, p, pv).

    q: [B, Cq, Hkv, G, hd]; k/v: [B, Ck, Hkv, hd];
    qpos: [Cq], kpos: [Ck] global positions.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    mask &= kpos[None, :] >= 0  # padding slots (local-window gather)
    return jnp.where(mask, s, NEG_INF)


def _online_update(carry, s, v):
    """Online-softmax state update.  s: [B,Hkv,G,Cq,Ck], v: [B,Ck,Hkv,hd]."""
    m, l, acc = carry
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v)
    acc_new = acc * corr[..., None].astype(acc.dtype) + pv
    return m_new, l_new, acc_new


def multi_head_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: jax.Array | int = 0,
    k_offset: jax.Array | int = 0,
    softcap: float | None = None,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
    skip_dead_chunks: bool = True,
) -> jax.Array:
    """Chunked GQA attention.

    q: [B, Sq, Hq, hd]; k, v: [B, Skv, Hkv, hd]; Hq % Hkv == 0.
    Returns [B, Sq, Hq, hd].  Never materializes [Sq, Skv].
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    chunk_q = min(chunk_q, Sq)
    chunk_kv = min(chunk_kv, Skv)
    nq = -(-Sq // chunk_q)
    nk = -(-Skv // chunk_kv)
    q = _pad_axis(q, 1, nq * chunk_q).reshape(B, nq, chunk_q, Hkv, G, hd)
    k = _pad_axis(k, 1, nk * chunk_kv).reshape(B, nk, chunk_kv, Hkv, hd)
    v = _pad_axis(v, 1, nk * chunk_kv).reshape(B, nk, chunk_kv, Hkv, hd)
    k = jnp.moveaxis(k, 1, 0)  # [nk, B, Ck, Hkv, hd] — scan leading axis
    v = jnp.moveaxis(v, 1, 0)

    kpos_all = jnp.arange(nk * chunk_kv) + k_offset
    kpos_all = jnp.where(jnp.arange(nk * chunk_kv) < Skv, kpos_all, -1)
    kpos_all = kpos_all.reshape(nk, chunk_kv)

    def one_q_chunk(qi, qc):
        qpos = q_offset + qi * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, xs):
            kc, vc, kpos = xs

            def live(carry):
                s = _chunk_attend(qc, kc, vc, qpos, kpos, causal=causal,
                                  window=window, scale=scale, softcap=softcap)
                return _online_update(carry, s, vc)

            if not skip_dead_chunks:
                return live(carry), ()
            # A kv chunk is dead if entirely in the causal future or
            # entirely outside the local window.
            dead = jnp.asarray(False)
            if causal:
                dead |= jnp.min(kpos) > jnp.max(qpos)
            if window is not None:
                dead |= jnp.max(kpos) <= jnp.min(qpos) - window
            return jax.lax.cond(dead, lambda c: c, live, carry), ()

        m0 = jnp.full((B, Hkv, G, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, chunk_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (k, v, kpos_all))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # [B, Hkv, G, Cq, hd]

    # Per-q-chunk remat: the backward recomputes the kv scan per q chunk
    # instead of saving every [Cq, Ck] score block — flash-attention
    # memory behavior (O(S*d) residuals, never O(S^2)).
    one_q_chunk = jax.checkpoint(one_q_chunk)
    outs = jax.lax.map(lambda xs: one_q_chunk(xs[0], xs[1]),
                       (jnp.arange(nq), jnp.moveaxis(q, 1, 0)))
    # outs: [nq, B, Hkv, G, Cq, hd] -> [B, Sq, Hq, hd]
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    out = out.reshape(B, nq * chunk_q, Hq, hd)[:, :Sq]
    return out.astype(v.dtype)


def local_attention(q, k, v, *, window: int, q_offset=0, softcap=None,
                    chunk_q: int = 512, mesh=None) -> jax.Array:
    """Sliding-window causal attention, batched over q chunks.

    Each q chunk attends to a [window + chunk_q] KV window — O(S * W).
    The chunk axis is *batched* (not a sequential lax.map) so it shards
    over "model" when nq divides the axis: a scan-over-chunks runs every
    trip on every SPMD rank, while the batched form splits the chunk loop
    across TP ranks (§Perf iteration on the collective-bound
    recurrentgemma prefill cell).  The windowed KV gather materializes
    span/chunk_q ~ 5x the kv bytes — cheap for MQA (Hkv=1) and sharded.
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    chunk_q = min(chunk_q, Sq)
    nq = -(-Sq // chunk_q)
    span = window + chunk_q  # kv positions visible to one q chunk

    qr = _pad_axis(q, 1, nq * chunk_q).reshape(B, nq, chunk_q, Hkv, G, hd)
    # Pad kv on the left by `window` so every window is in-bounds.
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))
    idx = (jnp.arange(nq)[:, None] * chunk_q
           + jnp.arange(span)[None, :])          # [nq, span] window gather
    kw = jnp.take(kp, idx, axis=1)               # [B, nq, span, Hkv, hd]
    vw = jnp.take(vp, idx, axis=1)

    from .common import act_spec_seq, shard_hint
    cspec = act_spec_seq(mesh, B, nq, n_trailing=4)
    qr = shard_hint(qr, cspec)
    kw = shard_hint(kw, cspec)
    vw = shard_hint(vw, cspec)

    # Positions relative to the sequence start (q_offset shifts q and k
    # equally, so it cancels in every mask comparison).
    qpos = jnp.arange(nq * chunk_q).reshape(nq, chunk_q)
    kpos = idx - window                          # < 0 -> left-pad slot
    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qr, kw).astype(jnp.float32)
    s = s * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    diff = qpos[:, :, None] - kpos[:, None, :]
    mask = (diff >= 0) & (diff < window) & (kpos >= 0)[:, None, :]
    s = jnp.where(mask[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p.astype(vw.dtype), vw)
    out = out.reshape(B, nq * chunk_q, Hq, hd)[:, :Sq]
    return out.astype(v.dtype)


def _pad_axis(x, axis, to):
    pad = to - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Decode (single-token) attention over a cache
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos, *, window: int | None = None,
                     ring: bool = False, softcap: float | None = None):
    """q: [B, 1, Hq, hd]; caches: [B, Skv, Hkv, hd]; pos: scalar int32
    (position of the *current* token, already written into the cache).

    ``ring=True`` means the cache is a ring buffer of ``Skv`` slots whose
    slot s holds logical position ``pos - ((pos - s) mod Skv)``.
    """
    B, _, Hq, hd = q.shape
    Skv, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    s = jnp.einsum("bqhgd,bkhd->bhgqk",
                   q.reshape(B, 1, Hkv, G, hd), k_cache)
    s = s.astype(jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    slots = jnp.arange(Skv)
    if ring:
        logical = pos - jnp.mod(pos - slots, Skv)
        valid = logical >= 0
    else:
        logical = slots
        valid = slots <= pos
    if window is not None:
        valid &= (pos - logical) < window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, Hq, hd)


def update_kv_cache(k_cache, v_cache, k_new, v_new, pos, *, ring=False):
    """Write [B, S_new, Hkv, hd] at position ``pos`` (ring: modulo slots)."""
    Skv = k_cache.shape[1]
    idx = jnp.mod(pos, Skv) if ring else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), idx, axis=1)
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + attend)
# ---------------------------------------------------------------------------

def attention_block(
    p: Params,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_fraction: float = 1.0,
    rope_theta: float = 10000.0,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    quant=None,
    cache: Params | None = None,
    pos: jax.Array | int = 0,
    xkv: jax.Array | None = None,
    use_rope: bool = True,
    mesh=None,
    tap: list | None = None,
    backend=None,
    page_table=None,
):
    """Projections + RoPE + attention.  Three modes:

    * ``cache is None``: full-sequence (train / one-shot prefill); returns
      (out, kv) where kv = (k, v) for the caller to install into a cache.
    * ``cache = {"k":..., "v":...}``: single-token decode at ``pos``;
      returns (out, new_cache).
    * ``cache = {"k_pages", "v_pages", "k_exp", "v_exp"}``: decode (S=1)
      or a prefill chunk (S>1, causal within the chunk) against the paged
      INT8 KV cache (``repro.serving.paged_cache``) — ``pos`` is a
      per-slot [B] vector (the chunk's FIRST position), ``page_table`` the
      [B, n_max] physical page ids, and the attention read dispatches
      through the ``repro.exec`` registry (``execute_kv_attention``).
      The chunked write/read is bit-identical to scanning the S=1 path.

    ``xkv`` (cross-attention): keys/values come from ``xkv`` instead of x,
    non-causal, no rope on kv by default (encoder output is position-free).
    """
    B, S, _ = x.shape
    q = dense(p["wq"], x, quant, tap=tap,
              backend=backend).reshape(B, S, n_heads, head_dim)
    src = xkv if xkv is not None else x
    k = dense(p["wk"], src, quant, tap=tap, backend=backend).reshape(
        B, src.shape[1], n_kv_heads, head_dim)
    v = dense(p["wv"], src, quant, tap=tap, backend=backend).reshape(
        B, src.shape[1], n_kv_heads, head_dim)
    # Keep attention compute sharded over heads (TP) — without these
    # constraints GSPMD can lose the head sharding through the reshape +
    # rope chain and replicate the whole S^2 score computation per shard.
    q = shard_hint(q, act_spec(mesh, B, heads=n_heads))
    k = shard_hint(k, act_spec(mesh, B, heads=n_kv_heads))
    v = shard_hint(v, act_spec(mesh, B, heads=n_kv_heads))

    paged = cache is not None and "k_pages" in cache
    if use_rope and xkv is None:
        if paged:  # per-slot positions: [B, 1] broadcasts over heads
            qpos = jnp.reshape(jnp.asarray(pos, jnp.int32),
                               (-1, 1)) + jnp.arange(S)
        else:
            qpos = pos + jnp.arange(S)
        q = apply_rope(q, qpos, fraction=rope_fraction, theta=rope_theta)
        k = apply_rope(k, qpos, fraction=rope_fraction, theta=rope_theta)

    if paged:  # decode / prefill chunk against the paged INT8 KV cache
        if window is not None or softcap is not None:
            raise NotImplementedError(
                "paged INT8 KV decode serves full attention only "
                "(no sliding window / softcap)")
        if S == 1:
            from repro.serving.paged_cache import paged_update_and_attend
            out, new_cache = paged_update_and_attend(
                cache, q[:, 0], k, v, pos, page_table, backend=backend)
            out = out[:, None]  # [B, Hq, hd] -> [B, 1, Hq, hd]
        else:
            from repro.serving.paged_cache import (
                paged_prefill_chunk_update_and_attend)
            out, new_cache = paged_prefill_chunk_update_and_attend(
                cache, q, k, v, pos, page_table, backend=backend)
    elif cache is not None:  # decode
        ring = window is not None
        kc, vc = update_kv_cache(cache["k"], cache["v"], k, v, pos, ring=ring)
        out = decode_attention(q, kc, vc, pos, window=window, ring=ring,
                               softcap=softcap)
        new_cache = {"k": kc, "v": vc}
    else:
        if xkv is not None:
            out = multi_head_attention(q, k, v, causal=False, softcap=softcap)
        elif window is not None:
            out = local_attention(q, k, v, window=window, q_offset=pos,
                                  softcap=softcap, mesh=mesh)
        else:
            out = multi_head_attention(q, k, v, causal=causal, q_offset=pos,
                                       softcap=softcap)
        new_cache = {"k": k, "v": v}

    out = dense(p["wo"], out.reshape(B, S, n_heads * head_dim), quant,
                tap=tap, backend=backend)
    return out, new_cache
