"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import QuantConfig
from repro.data import DataConfig, SyntheticCorpus
from repro.models.config import ModelConfig
from repro.models.model import forward, init_lm, lm_loss
from repro.optim import OptimConfig, apply_updates, decay_mask, \
    init_opt_state

# The small QAT testbed used by the accuracy benchmarks (Table I / Fig 5):
# a 4-layer GQA transformer LM on the deterministic synthetic corpus.
QAT_CFG = ModelConfig(name="qat-bench", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=512, dtype="float32", scan_layers=False)
QAT_DATA = DataConfig(vocab=512, seq_len=64, global_batch=8, seed=11)


def train_qat(cfg: ModelConfig, steps: int = 60, lr: float = 3e-3,
              eval_steps: int = 4, seed: int = 0):
    """Train + eval one QAT variant; returns (final_train, eval_loss).

    PSUM quantizer scales are calibrated from a forward pass before
    training (running-accumulation statistics) — without this every ap
    starts at a generic magnitude and the early QAT signal is identical
    across gs (observed; the paper also calibrates before QAT)."""
    corpus = SyntheticCorpus(QAT_DATA)
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    if cfg.policy is not None:
        from repro.quant import calibrate_model
        b0 = corpus.batch_at(999)
        params = calibrate_model(params, cfg,
                                 {"tokens": jnp.asarray(b0["tokens"])})
    ocfg = OptimConfig(lr=lr, warmup_steps=max(steps // 10, 2),
                       total_steps=steps, weight_decay=0.0)
    state = init_opt_state(params, ocfg)
    mask = decay_mask(params)

    @jax.jit
    def step(params, state, tokens, labels):
        def loss_fn(p):
            return lm_loss(forward(p, cfg, tokens), labels)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = apply_updates(params, g, state, ocfg, mask)
        return params, state, loss

    last = None
    for s in range(steps):
        b = corpus.batch_at(s)
        params, state, last = step(params, state, jnp.asarray(b["tokens"]),
                                   jnp.asarray(b["labels"]))

    @jax.jit
    def eval_loss(params, tokens, labels):
        return lm_loss(forward(params, cfg, tokens), labels)

    tot = 0.0
    for s in range(10_000, 10_000 + eval_steps):
        b = corpus.batch_at(s)
        tot += float(eval_loss(params, jnp.asarray(b["tokens"]),
                               jnp.asarray(b["labels"])))
    return float(last), tot / eval_steps


def quant_variants(gs_values=(1, 2, 3, 4), n_p: int = 8) -> dict:
    """Named per-layer policies (uniform) for the accuracy sweep."""
    from repro.quant import quant_variants as _qv
    return _qv(gs_values=gs_values, n_p=n_p)


def timed(fn, *args, reps: int = 5, warmup: int = 2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6, out  # us
