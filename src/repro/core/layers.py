"""Quantized linear layers — APSQ as a first-class, composable feature.

Every model in the zoo funnels its projection GEMMs through ``quant_dense``
so that enabling W8A8 + PSUM quantization (PSQ/APSQ, any group size) is a
pure config change (``QuantConfig``), exactly as the paper integrates APSQ
into QAT (§IV-A).

Fake-quant semantics (QAT): weights/activations through LSQ [10]; PSUMs
through PO2-scale quantizers via Algorithm 1.  Deployment integer path is
``repro.kernels.apsq_matmul``.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .apsq import apsq_matmul
from .quantizers import (
    init_alpha_from,
    lsq_quantize,
    qrange,
)

PSUM_MODES = ("none", "psq", "apsq")


@dataclasses.dataclass(frozen=True)
class PsumQuantConfig:
    """PSUM handling for the simulated IS/WS accelerator."""

    mode: str = "none"  # none | psq | apsq
    gs: int = 2         # group size (Algorithm 1); psq == apsq with gs>=n_p
    n_p: int = 8        # simulated #PSUM tiles along K (= ceil(C_i/P_ci))
    bits: int = 8

    def __post_init__(self):
        if self.mode not in PSUM_MODES:
            raise ValueError(f"psum mode must be one of {PSUM_MODES}")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """W8A8 fake-quant + optional PSUM quantization."""

    enabled: bool = False
    w_bits: int = 8
    a_bits: int = 8
    per_channel_w: bool = True
    psum: PsumQuantConfig = dataclasses.field(default_factory=PsumQuantConfig)

    @staticmethod
    def w8a8() -> "QuantConfig":
        return QuantConfig(enabled=True)

    @staticmethod
    def apsq(gs: int = 2, n_p: int = 8) -> "QuantConfig":
        return QuantConfig(enabled=True, psum=PsumQuantConfig("apsq", gs=gs, n_p=n_p))

    @staticmethod
    def psq(n_p: int = 8) -> "QuantConfig":
        return QuantConfig(enabled=True, psum=PsumQuantConfig("psq", n_p=n_p))


def effective_n_p(k: int, requested: int) -> int:
    """Largest divisor of K that is <= requested (K-tiling must be exact)."""
    n = max(1, min(requested, k))
    while k % n:
        n -= 1
    return n


def quant_params_init(w: jax.Array, cfg: QuantConfig) -> dict:
    """Quantizer state for one linear with (flattened) weight [K, N]."""
    k = w.shape[0]
    n = int(w.size // k)
    w2d = w.reshape(k, n)
    if cfg.per_channel_w:
        _, qp = qrange(cfg.w_bits, True)
        aw = 2.0 * jnp.mean(jnp.abs(w2d), axis=0) / math.sqrt(qp) + 1e-12
    else:
        aw = init_alpha_from(w2d, cfg.w_bits)
    qp = {"aw": aw, "ax": jnp.asarray(1.0, jnp.float32)}
    if cfg.psum.mode != "none":
        n_p = effective_n_p(k, cfg.psum.n_p)
        # PSUM scales start at a generic magnitude; ``calibrate_dense``
        # refines them from data (running-accumulation statistics).
        qp["ap"] = jnp.zeros((n_p,), jnp.float32) + jnp.log2(jnp.asarray(16.0))
    return qp


def calibrate_dense(qp: dict, x: jax.Array, w: jax.Array, cfg: QuantConfig) -> dict:
    """Refine activation & PSUM scales from a calibration batch.

    PSUM scales are initialized from the *running accumulation* magnitude
    (cumsum over tiles) — the quantity APSQ actually quantizes — so early
    tiles get small scales and late tiles get large ones.
    """
    k = w.shape[0]
    n = int(w.size // k)
    w2d = w.reshape(k, n).astype(jnp.float32)
    x2d = x.reshape(-1, k).astype(jnp.float32)
    out = dict(qp)
    out["ax"] = init_alpha_from(x2d, cfg.a_bits)
    if "ap" in qp:
        n_p = qp["ap"].shape[0]
        kt = k // n_p
        tiles = jnp.einsum(
            "bpk,pkn->pbn",
            x2d.reshape(-1, n_p, kt),
            w2d.reshape(n_p, kt, n),
        )
        running = jnp.cumsum(tiles, axis=0)
        _, qpmax = qrange(cfg.psum.bits, True)
        mags = 2.0 * jnp.mean(jnp.abs(running), axis=(1, 2)) / math.sqrt(qpmax)
        out["ap"] = jnp.log2(jnp.maximum(mags, 1e-6))
    return out


def quant_dense(
    x: jax.Array,
    w: jax.Array,
    qp: dict | None,
    cfg: QuantConfig,
) -> jax.Array:
    """``x @ w`` with optional W8A8 fake quant and PSQ/APSQ PSUM handling.

    x: [..., K];  w: [K, ...] (trailing dims flattened to N internally).
    Returns [..., *w.shape[1:]] in x.dtype.
    """
    out_shape = x.shape[:-1] + w.shape[1:]
    if not cfg.enabled or qp is None:
        y = jax.lax.dot_general(
            x, w.reshape(w.shape[0], -1),
            (((x.ndim - 1,), (0,)), ((), ())),
        )
        return y.reshape(out_shape)

    k = w.shape[0]
    w2d = w.reshape(k, -1)
    in_dtype = x.dtype
    xf = x.astype(jnp.float32)
    wf = w2d.astype(jnp.float32)
    xq = lsq_quantize(xf, qp["ax"], bits=cfg.a_bits)
    wq = lsq_quantize(wf, qp["aw"], bits=cfg.w_bits)

    mode = cfg.psum.mode
    if mode == "none":
        y = jax.lax.dot_general(
            xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    else:
        # Gather the FSDP(K)-shard of the weight ONCE before the PSUM tile
        # loop, KEEPING the TP(N) shard: without this every one of the n_p
        # tile GEMMs contracts a data-sharded K slice and all-reduces its
        # partial sums — n_p x the collective bytes of the unquantized
        # GEMM.  Full replication (P(None, None)) was measured and
        # REFUTED — it drags replicated weights/grads through the scan
        # residuals (§Perf it2/it3 on the APSQ cell).
        try:
            wq = jax.lax.with_sharding_constraint(
                wq, jax.sharding.PartitionSpec(None, "model"))
        except (ValueError, RuntimeError):
            pass  # no ambient mesh (unsharded smoke/QAT runs)
        n_p = qp["ap"].shape[0]
        gs = n_p if mode == "psq" else cfg.psum.gs
        y = apsq_matmul(xq, wq, qp["ap"], n_p=n_p, gs=gs, bits=cfg.psum.bits)
    return y.astype(in_dtype).reshape(out_shape)
