"""Pallas APSQ kernel: bit-exact vs the pure-jnp integer oracle.

Sweeps shapes / gs / n_p / adversarial exponents in interpret mode (the
kernel body executes in Python on CPU; on TPU the same BlockSpecs run on
hardware).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.apsq_matmul import (
    accumulator_vmem_bytes,
    apsq_matmul_f32,
    apsq_matmul_int8,
    apsq_matmul_ref,
    baseline_matmul_int8,
    baseline_matmul_ref,
    choose_exps,
    dequantize_psum,
    quantize_psum,
    rshift_round,
)

def _codes(key, shape):
    return jax.random.randint(key, shape, -128, 128, jnp.int8)


@pytest.mark.parametrize("m,k,n", [(8, 32, 16), (16, 64, 32), (32, 128, 128),
                                   (8, 40, 16), (130, 96, 130)])
@pytest.mark.parametrize("gs", [1, 2, 3, 4])
def test_kernel_bit_exact_vs_oracle(m, k, n, gs):
    key = jax.random.PRNGKey(m * 1000 + k + gs)
    for n_p in (1, 2, 4):
        if k % n_p:
            continue
        x = _codes(key, (m, k))
        w = _codes(jax.random.fold_in(key, 1), (k, n))
        exps = choose_exps(x, w, n_p=n_p, gs=gs)
        ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
        out = apsq_matmul_int8(x, w, exps, gs=gs, interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@given(st.integers(1, 8), st.integers(1, 5), st.integers(0, 10))
def test_kernel_property_shapes(n_p, gs, seed):
    key = jax.random.PRNGKey(seed)
    m, n = 8, 16
    k = n_p * 8
    x = _codes(key, (m, k))
    w = _codes(jax.random.fold_in(key, 1), (k, n))
    exps = choose_exps(x, w, n_p=n_p, gs=gs)
    ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
    out = apsq_matmul_int8(x, w, exps, gs=gs, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_adversarial_exponents():
    """Extreme exponents (0 and large) must clip/shift identically."""
    key = jax.random.PRNGKey(7)
    x = _codes(key, (8, 32))
    w = _codes(jax.random.fold_in(key, 1), (32, 16))
    for exps in ([0, 0, 0, 0], [20, 20, 20, 20], [0, 20, 0, 20]):
        e = jnp.asarray(exps, jnp.int32)
        ref = apsq_matmul_ref(x, w, e, n_p=4, gs=2)
        out = apsq_matmul_int8(x, w, e, gs=2, interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_baseline_kernel_equals_int_matmul():
    key = jax.random.PRNGKey(8)
    x = _codes(key, (16, 64))
    w = _codes(jax.random.fold_in(key, 1), (64, 32))
    out = baseline_matmul_int8(x, w, n_p=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(baseline_matmul_ref(x, w)),
                                  np.asarray(out))


def test_rshift_round_half_up():
    v = jnp.asarray([5, -5, 6, -6, 7], jnp.int32)
    # (v + 2) >> 2 == round-half-up(v / 4): 1.25->1, -1.25->-1, 1.5->2,
    # -1.5->-1 (half rounds toward +inf), 1.75->2
    np.testing.assert_array_equal(np.asarray(rshift_round(v, 2)),
                                  [1, -1, 2, -1, 2])
    np.testing.assert_array_equal(np.asarray(rshift_round(v, 0)),
                                  np.asarray(v))


def test_quant_dequant_roundtrip_within_one_lsb():
    v = jnp.arange(-500, 500, 7, dtype=jnp.int32)
    e = jnp.asarray(3, jnp.int32)
    code = quantize_psum(v, e)
    back = dequantize_psum(code, e)
    assert int(jnp.max(jnp.abs(back - v))) <= 2 ** 3 // 2 + 1


def test_apsq_error_bounded_vs_exact():
    """APSQ output within a few shifted LSBs of the exact INT32 GEMM."""
    key = jax.random.PRNGKey(9)
    x = _codes(key, (16, 64))
    w = _codes(jax.random.fold_in(key, 1), (64, 32))
    exact = baseline_matmul_ref(x, w)
    for gs in (1, 2, 4):
        exps = choose_exps(x, w, n_p=8, gs=gs)
        out = apsq_matmul_ref(x, w, exps, n_p=8, gs=gs)
        lsb = 2.0 ** float(jnp.max(exps))
        err = float(jnp.max(jnp.abs((out - exact))))
        assert err <= lsb * (8 / gs + 2), (gs, err, lsb)


def test_f32_wrapper_scales():
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(key, (8, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 16)) * 0.1
    ax = float(jnp.max(jnp.abs(x))) / 127
    aw = float(jnp.max(jnp.abs(w))) / 127
    xq = jnp.clip(jnp.round(x / ax), -128, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / aw), -128, 127).astype(jnp.int8)
    exps = choose_exps(xq, wq, n_p=4, gs=2)
    y = apsq_matmul_f32(x, w, exps, gs=2, ax=ax, aw=aw, interpret=True)
    rel = float(jnp.mean(jnp.abs(y - x @ w)) / jnp.mean(jnp.abs(x @ w)))
    assert rel < 0.1, rel


def test_accumulator_working_set():
    b = accumulator_vmem_bytes(128, 128, gs=1)
    assert b["apsq_banks"] * 4 == b["baseline_int32"]  # beta 4 -> 1
    b4 = accumulator_vmem_bytes(128, 128, gs=4)
    assert b4["apsq_banks"] == b4["baseline_int32"]  # parity at gs=4


# ---------------------------------------------------------------------------
# Kernel-vs-oracle parity grid: serving shapes, ragged K, exponent layouts
# ---------------------------------------------------------------------------

# (m, k) cells: decode M=1, small/batched prefill M, ragged K (K % n_p != 0
# for some n_p below -> remainder PSUM group), and an unaligned N.
PARITY_SHAPES = [
    (1, 64, 32),     # decode: one token against the cache
    (1, 37, 16),     # decode + ragged K for every n_p > 1
    (8, 40, 24),     # small batch, ragged for n_p in (3, 16)
    (64, 96, 48),    # batched prefill
    (130, 100, 130), # prefill crossing block_m/block_n boundaries, ragged
]


@pytest.mark.parametrize("m,k,n", PARITY_SHAPES)
@pytest.mark.parametrize("gs", [1, 2, 4])
@pytest.mark.parametrize("n_p", [1, 3, 4, 16])
def test_parity_grid_kernel_vs_oracle(m, k, n, gs, n_p):
    """The full serving grid: every (shape, gs, n_p) cell bit-exact,
    including ragged K handled by the zero-contribution remainder group."""
    key = jax.random.PRNGKey(m * 7919 + k * 31 + n_p * 7 + gs)
    x = _codes(key, (m, k))
    w = _codes(jax.random.fold_in(key, 1), (k, n))
    exps = choose_exps(x, w, n_p=n_p, gs=gs)
    ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
    out = apsq_matmul_int8(x, w, exps, gs=gs, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("m,k,n,n_p,gs", [(8, 32, 16, 4, 2),
                                          (1, 48, 16, 4, 3),
                                          (16, 64, 130, 8, 2),
                                          (4, 30, 20, 4, 2)])
def test_parity_per_column_exponents(m, k, n, n_p, gs):
    """[n_p, N] exponents (per-channel weight-scale export layout): the
    kernel's VMEM exponent block must match the broadcasting oracle."""
    key = jax.random.PRNGKey(m + k + n)
    x = _codes(key, (m, k))
    w = _codes(jax.random.fold_in(key, 1), (k, n))
    base = choose_exps(x, w, n_p=n_p, gs=gs)
    exps = base[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :] % 3
    ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
    out = apsq_matmul_int8(x, w, exps, gs=gs, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_ragged_k_equals_explicitly_padded():
    """Ragged K == running the kernel on zero-padded codes (the remainder
    group contributes nothing)."""
    from repro.kernels.apsq_matmul import pad_ragged_k
    key = jax.random.PRNGKey(13)
    x = _codes(key, (8, 45))
    w = _codes(jax.random.fold_in(key, 1), (45, 16))
    n_p, gs = 4, 2
    exps = choose_exps(x, w, n_p=n_p, gs=gs)
    xp, wp = pad_ragged_k(x, w, n_p)
    assert xp.shape[1] == 48 and wp.shape[0] == 48
    ragged = apsq_matmul_int8(x, w, exps, gs=gs, interpret=True)
    padded = apsq_matmul_int8(xp, wp, exps, gs=gs, interpret=True)
    np.testing.assert_array_equal(np.asarray(ragged), np.asarray(padded))
    base = baseline_matmul_int8(x, w, n_p=n_p, interpret=True)
    np.testing.assert_array_equal(np.asarray(base),
                                  np.asarray(baseline_matmul_ref(x, w)))


# ---------------------------------------------------------------------------
# m=1 decode fast path (single grid row, K reduction unrolled in-register)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,n,n_p,gs", [
    (32, 16, 4, 2),    # tiny
    (45, 16, 4, 2),    # ragged K -> remainder PSUM group
    (64, 32, 8, 3),    # PSQ-ish tail inside a group
    (48, 16, 1, 1),    # n_p=1: single final tile
])
def test_m1_fastpath_bit_exact(k, n, n_p, gs):
    """block_m=1 takes the fast path (no bank scratch, no K grid steps);
    it must stay bit-identical to the oracle AND the generic grid."""
    key = jax.random.PRNGKey(k * 7 + n)
    x = _codes(key, (1, k))
    w = _codes(jax.random.fold_in(key, 1), (k, n))
    exps = choose_exps(x, w, n_p=n_p, gs=gs)
    ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
    fast = apsq_matmul_int8(x, w, exps, gs=gs, block_m=1, interpret=True)
    generic = apsq_matmul_int8(x, w, exps, gs=gs, block_m=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(fast))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(generic))


def test_m1_fastpath_per_column_exponents():
    """The fast path reads [n_p, N] banks whole — per-column shifts must
    match the broadcasting oracle."""
    key = jax.random.PRNGKey(29)
    k, n, n_p, gs = 64, 24, 4, 2
    x = _codes(key, (1, k))
    w = _codes(jax.random.fold_in(key, 1), (k, n))
    base = choose_exps(x, w, n_p=n_p, gs=gs)
    exps = base[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :] % 3
    ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
    out = apsq_matmul_int8(x, w, exps, gs=gs, block_m=1, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_m1_default_resolution_takes_fastpath():
    """With blocks unset, M=1 resolves block_m=1 via the autotune
    heuristic — the decode shape must not pad to sublane rows."""
    from repro.kernels import autotune
    cfg = autotune.get_block_config(1, 64, 32, n_p=4, gs=2)
    assert cfg.block_m == 1
    key = jax.random.PRNGKey(31)
    x = _codes(key, (1, 64))
    w = _codes(jax.random.fold_in(key, 1), (64, 32))
    exps = choose_exps(x, w, n_p=4, gs=2)
    ref = apsq_matmul_ref(x, w, exps, n_p=4, gs=2)
    out = apsq_matmul_int8(x, w, exps, gs=2, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))


def test_full_exp_layout_matches_blocked():
    """exp_layout="full" (whole [n_p, N] bank resident, dynamic column
    slice per tile) == "blocked" == oracle."""
    key = jax.random.PRNGKey(37)
    m, k, n, n_p, gs = 8, 64, 32, 4, 2
    x = _codes(key, (m, k))
    w = _codes(jax.random.fold_in(key, 1), (k, n))
    base = choose_exps(x, w, n_p=n_p, gs=gs)
    exps = base[:, None] + jnp.arange(n, dtype=jnp.int32)[None, :] % 2
    ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
    for layout in ("blocked", "full"):
        out = apsq_matmul_int8(x, w, exps, gs=gs, block_m=8, block_n=16,
                               exp_layout=layout, interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out),
                                      err_msg=f"exp_layout={layout}")


# ---------------------------------------------------------------------------
# Fused MoE expert grid (one pallas_call for all E experts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_experts", [1, 4, 8])
def test_expert_fused_bit_exact_vs_unrolled(n_experts):
    """ONE fused launch over the stacked [E, ...] bank == the E unrolled
    single-expert launches == the oracle, expert by expert."""
    from repro.kernels.apsq_matmul import apsq_expert_matmul_int8
    key = jax.random.PRNGKey(41 + n_experts)
    m, k, n, n_p, gs = 8, 32, 16, 4, 2
    x = _codes(key, (n_experts, m, k))
    w = _codes(jax.random.fold_in(key, 1), (n_experts, k, n))
    exps = jnp.stack([choose_exps(x[e], w[e], n_p=n_p, gs=gs)
                      for e in range(n_experts)])
    fused = apsq_expert_matmul_int8(x, w, exps, gs=gs, interpret=True)
    assert fused.shape == (n_experts, m, n)
    for e in range(n_experts):
        ref = apsq_matmul_ref(x[e], w[e], exps[e], n_p=n_p, gs=gs)
        single = apsq_matmul_int8(x[e], w[e], exps[e], gs=gs,
                                  interpret=True)
        np.testing.assert_array_equal(np.asarray(ref),
                                      np.asarray(fused[e]),
                                      err_msg=f"expert {e} vs oracle")
        np.testing.assert_array_equal(np.asarray(single),
                                      np.asarray(fused[e]),
                                      err_msg=f"expert {e} vs unrolled")


def test_expert_fused_ragged_k_and_per_column_banks():
    """Ragged K (remainder PSUM group) and [E, n_p, N] per-column banks
    through the fused grid."""
    from repro.kernels.apsq_matmul import apsq_expert_matmul_int8
    key = jax.random.PRNGKey(43)
    E, m, k, n, n_p, gs = 3, 8, 45, 16, 4, 2
    x = _codes(key, (E, m, k))
    w = _codes(jax.random.fold_in(key, 1), (E, k, n))
    base = jnp.stack([choose_exps(x[e], w[e], n_p=n_p, gs=gs)
                      for e in range(E)])
    exps = base[:, :, None] + jnp.arange(n, dtype=jnp.int32)[None, None] % 3
    out = apsq_expert_matmul_int8(x, w, exps, gs=gs, interpret=True)
    for e in range(E):
        ref = apsq_matmul_ref(x[e], w[e], exps[e], n_p=n_p, gs=gs)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(out[e]),
                                      err_msg=f"expert {e}")


def test_expert_fused_baseline_w8a8():
    """The fused INT32-accumulator baseline == per-expert integer matmul."""
    from repro.kernels.apsq_matmul import baseline_expert_matmul_int8
    key = jax.random.PRNGKey(47)
    E, m, k, n = 2, 8, 32, 16
    x = _codes(key, (E, m, k))
    w = _codes(jax.random.fold_in(key, 1), (E, k, n))
    out = baseline_expert_matmul_int8(x, w, interpret=True)
    for e in range(E):
        np.testing.assert_array_equal(
            np.asarray(baseline_matmul_ref(x[e], w[e])), np.asarray(out[e]))
