"""Serving example: continuous batching over batched requests.

    PYTHONPATH=src python examples/serve.py --arch tinyllama-1.1b

Uses the reduced (smoke) config so it runs on CPU; on a TPU slice the same
engine serves the full config under the production mesh.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models.model import init_lm
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(params, cfg, max_batch=args.max_batch,
                           cache_len=128, prefill_chunk=16)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(4, 24))),
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in done)
    print(f"[serve] arch={args.arch} {len(done)} requests, {total} tokens, "
          f"{total / dt:.1f} tok/s (CPU, reduced config)")
    for r in sorted(done, key=lambda r: r.uid)[:5]:
        print(f"  req {r.uid:2d} prompt[{len(r.tokens):2d}] -> {r.out}")


if __name__ == "__main__":
    main()
