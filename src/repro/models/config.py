"""ModelConfig — one dataclass describing every architecture in the zoo.

An architecture is a repeating ``block_pattern`` of time-mix kinds
("attn" | "local" | "rwkv" | "rglru") with a channel mix chosen by ``mlp``
("swiglu" | "gelu" | "moe" | "rwkv_cm"), plus embeddings / heads / optional
encoder stack and modality frontend stubs.  The paper's technique rides on
``quant`` (W8A8 + PSQ/APSQ on every projection GEMM).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import QuantConfig

BLOCK_KINDS = ("attn", "local", "rwkv", "rglru")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    mlp: str = "swiglu"               # swiglu | gelu | moe | rwkv_cm
    block_pattern: tuple = ("attn",)
    rope_fraction: float = 1.0
    rope_theta: float = 10000.0
    local_window: int = 2048
    softcap: float | None = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # RWKV
    wkv_impl: str = "scan"            # scan | chunked  (§Perf)
    wkv_chunk: int = 32               # chunk length for the chunked WKV
    # RG-LRU
    d_rnn: int | None = None
    # encoder-decoder (seamless)
    encdec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: precomputed embeddings are model inputs
    frontend: str | None = None       # audio | vision
    n_frontend_tokens: int = 0
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    # per-layer policy (repro.quant.policy.QuantPolicy); when set it takes
    # precedence over the global ``quant`` config at init time
    quant_policy: object | None = None
    remat: bool = True
    remat_policy: str = "none"        # none | dots  ("none" = save nothing)
    scan_layers: bool = True          # False: python-unrolled units (QAT
                                      # calibration taps, tiny models)
    # attention chunking (flash-style); tuned per shape by the launcher
    chunk_q: int = 512
    chunk_kv: int = 1024
    # loss
    z_loss: float = 0.0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pattern_kinds(self) -> tuple:
        return tuple(self.block_pattern)

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def n_rem(self) -> int:
        return self.n_layers % len(self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no full-attention layer exists (long_500k eligibility)."""
        return all(k in ("rwkv", "rglru", "local") for k in self.block_pattern)

    @property
    def policy(self):
        """The per-layer quantization policy driving param init.

        ``quant_policy`` when set; otherwise the global ``quant`` config
        as the trivial uniform policy; None when quantization is off.
        """
        if self.quant_policy is not None:
            return self.quant_policy
        if self.quant.enabled:
            from repro.quant.policy import QuantPolicy
            return QuantPolicy.uniform(self.quant)
        return None

    def with_quant(self, quant) -> "ModelConfig":
        """Set a global ``QuantConfig`` or a per-layer ``QuantPolicy``."""
        if isinstance(quant, QuantConfig):
            return dataclasses.replace(self, quant=quant, quant_policy=None)
        return dataclasses.replace(self, quant_policy=quant)

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self):
        assert self.n_heads % max(self.n_kv_heads, 1) == 0
        for k in self.block_pattern:
            assert k in BLOCK_KINDS, k
        if self.mlp == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if "rglru" in self.block_pattern:
            assert self.d_rnn is not None
        return self


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell: what gets lowered in the dry-run."""
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
