"""Serving benchmark: continuous batching under Poisson load.

Drives the ``PagedServingEngine`` (INT8 paged KV cache, scheduler with
admission/eviction, prefill bucketing) with a synthetic open-loop load:
request arrivals are a Poisson process over decode steps, prompt and
output lengths are mixed, and every stream decodes greedily.  Reported:

  * tokens/s (aggregate decode throughput across all streams),
  * prefill tokens/s (prompt tokens through the chunked-prefill forwards
    divided by the wall time spent inside them),
  * p50/p99 per-token latency (wall-clock of the engine step that
    produced each token — a fused macro-step's wall is attributed to
    every token it drained) and p50/p99 time-to-first-token,
  * a host-overhead breakdown per load cell: wall time split into the
    fused-decode window, the prefill-chunk window, and residual host
    bookkeeping, plus dispatches per token and the macro-step scan-
    length histogram (``host_breakdown``),
  * a ``--decode-horizon`` sweep section (``horizon_sweep`` records):
    a saturated decode-bound cell — every stream generates the same
    fixed token count (a multiple of every swept horizon) and all
    requests queue upfront, so scan-lane waste is structurally zero —
    rerun across fused scan lengths {1, 4, 8, 16} (full) or {1, 8}
    (smoke).  The runs differ only in dispatch granularity: the clean
    before/after of moving the decode loop on device, which CI gates
    via ``check_serving_floor.py --min-horizon-speedup``.  (The Poisson
    cells keep measuring admission churn, where short streams favour
    small horizons — see the engine docstring's guidance.)
  * scheduler counters (admissions, preemptions) under the page pool,
  * KV-cache bytes: paged INT8 pools vs the dense f32 / native-dtype
    caches the ``ServingEngine`` baseline would allocate.

Before generating load the bench runs the parity gate the CI ``serve``
job rides on: greedy outputs of the batched engine must be
token-identical to the single-stream engine (same pools, batch 1), and
the oracle and interpret-mode Pallas backends must agree token-for-token
through the ``kv_attention`` exec op family.  A parity failure is a
hard error — throughput numbers from a wrong engine are worthless.

``--smoke`` (the CI job) runs 64 concurrent streams on the smoke
tinyllama config; the full run drives hundreds of streams.  ``--json
BENCH_serving.json`` emits machine-readable records so the serving
trajectory is tracked across PRs like ``BENCH_kernel.json``.
"""
import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.exec import PallasBackend
from repro.kernels import autotune
from repro.models.model import init_lm
from repro.serving import PagedServingEngine, Request, paged_cache_bytes


def _engine(params, cfg, *, max_batch, n_pages, backend="auto",
            page_size=16, prefill_chunk=16, max_pages_per_slot=None,
            decode_horizon=8, profile=True):
    return PagedServingEngine(
        params, cfg, max_batch=max_batch, page_size=page_size,
        n_pages=n_pages, prefill_chunk=prefill_chunk, backend=backend,
        max_pages_per_slot=max_pages_per_slot,
        decode_horizon=decode_horizon, profile=profile)


def _requests(cfg, n_streams, rng, *, max_new_lo=4, max_new_hi=12,
              prompt_lo=4, prompt_hi=14):
    reqs = []
    for i in range(n_streams):
        L = int(rng.integers(prompt_lo, prompt_hi))
        reqs.append(Request(
            uid=i, tokens=rng.integers(0, cfg.vocab, L).astype(np.int32),
            max_new_tokens=int(rng.integers(max_new_lo, max_new_hi))))
    return reqs


# ---------------------------------------------------------------------------
# Parity gate (what CI's `serve` target asserts before trusting numbers)
# ---------------------------------------------------------------------------

def run_parity(params, cfg, print_fn=print, records: list | None = None):
    """Batched == single-stream, oracle == pallas, and the fused decode
    horizon == per-token heartbeats — all token-for-token."""
    rng = np.random.default_rng(7)
    probes = _requests(cfg, 4, rng, max_new_lo=6, max_new_hi=7)

    def outs(max_batch, backend, decode_horizon=8):
        eng = _engine(params, cfg, max_batch=max_batch, n_pages=48,
                      backend=backend, decode_horizon=decode_horizon)
        done = eng.run([Request(uid=r.uid, tokens=r.tokens,
                                max_new_tokens=r.max_new_tokens)
                        for r in probes])
        return {r.uid: r.out for r in done}

    single = outs(1, "oracle")
    batched = outs(4, "oracle")
    pallas = outs(4, PallasBackend(interpret=True))
    stepwise = outs(4, "oracle", decode_horizon=1)
    batch_ok = batched == single
    backend_ok = pallas == batched
    horizon_ok = stepwise == batched
    print_fn(f"serving,parity,batched_eq_single={batch_ok},"
             f"pallas_eq_oracle={backend_ok},"
             f"horizon_eq_stepwise={horizon_ok}")
    if records is not None:
        records.append({"section": "parity", "streams": len(probes),
                        "batched_eq_single": batch_ok,
                        "pallas_eq_oracle": backend_ok,
                        "horizon_eq_stepwise": horizon_ok})
    assert batch_ok, "batched paged engine diverged from single-stream"
    assert backend_ok, "pallas kv_attention diverged from oracle"
    assert horizon_ok, "fused decode horizon diverged from per-token steps"
    return batch_ok and backend_ok and horizon_ok


# ---------------------------------------------------------------------------
# Load generator
# ---------------------------------------------------------------------------

def run_load(params, cfg, *, n_streams, max_batch, arrival_rate,
             seed=0, print_fn=print, records: list | None = None,
             backend="auto", decode_horizon=8, section="load"):
    """Open-loop Poisson load: ``arrival_rate`` requests per decode step."""
    rng = np.random.default_rng(seed)
    reqs = _requests(cfg, n_streams, rng)
    inter = rng.exponential(1.0 / arrival_rate, n_streams)
    arrival_step = np.floor(np.cumsum(inter)).astype(int)

    page_size = 16
    # Pool sized so a full batch fits without thrashing but eviction is
    # still reachable under bursts.
    per_slot = -(-(14 + 12 + 1) // page_size) + 1
    n_pages = max_batch * per_slot + 1
    # Bound the page table to the workload footprint: the engine default
    # (n_pages - 1 columns) makes every decode gather/attend over the
    # whole pool — hundreds of dead positions per live token.
    eng = _engine(params, cfg, max_batch=max_batch, n_pages=n_pages,
                  backend=backend, page_size=page_size,
                  max_pages_per_slot=per_slot,
                  decode_horizon=decode_horizon)

    # Warm the compiles (pow2 prefill chunk shapes + every pow2 scan
    # length the horizon can shrink to) so the latency percentiles
    # measure steady-state serving, not tracing.
    h = 1
    while h <= decode_horizon:
        eng.run([Request(uid=-1, tokens=np.zeros(15, np.int32),
                         max_new_tokens=h + 1)])
        h *= 2
    eng.reset_counters()

    pending = sorted(zip(arrival_step, reqs), key=lambda x: x[0])
    arrive_t: dict = {}
    ttft: dict = {}
    tok_lat: list = []
    step = 0
    n_done = 0
    t_start = time.perf_counter()
    while pending or eng.sched.waiting or any(
            s is not None for s in eng.sched.slots):
        while pending and pending[0][0] <= step:
            _, r = pending.pop(0)
            arrive_t[r.uid] = time.perf_counter()
            eng.add_request(r)
        before = {r.uid: len(r.out) for r in reqs}
        t0 = time.perf_counter()
        n_done += len(eng.step())
        dt = time.perf_counter() - t0
        for r in reqs:
            new = len(r.out) - before[r.uid]
            if new and r.uid not in ttft and before[r.uid] == 0:
                ttft[r.uid] = time.perf_counter() - arrive_t[r.uid]
            tok_lat.extend([dt] * new)
        step += 1
    wall = time.perf_counter() - t_start
    eng.sched.assert_invariants()

    total_tokens = sum(len(r.out) for r in reqs)
    assert n_done == n_streams
    lat_ms = np.asarray(tok_lat) * 1e3
    ttft_ms = np.asarray(list(ttft.values())) * 1e3
    bytes_ = paged_cache_bytes(cfg, n_pages=n_pages, page_size=page_size,
                               max_batch=max_batch,
                               cache_len=per_slot * page_size)
    stats = eng.sched.stats
    prefill_tps = (eng.prefill_tokens / eng.prefill_seconds
                   if eng.prefill_seconds else 0.0)
    # Host-overhead breakdown: wall splits into the fused-decode window
    # (dispatch -> token-block drain, device compute included), the
    # prefill-chunk window (profile=True syncs it), and what's left —
    # pure host bookkeeping (scheduler, page walks, request churn).
    host_s = max(wall - eng.decode_seconds - eng.prefill_seconds, 0.0)
    dispatches = eng.decode_dispatches + eng.prefill_dispatches
    print_fn(
        f"serving,{section},streams={n_streams},max_batch={max_batch},"
        f"decode_horizon={decode_horizon},"
        f"steps={step},tokens={total_tokens},"
        f"tokens_per_s={total_tokens / wall:.1f},"
        f"prefill_tokens_per_s={prefill_tps:.1f},"
        f"p50_ms={np.percentile(lat_ms, 50):.1f},"
        f"p99_ms={np.percentile(lat_ms, 99):.1f},"
        f"ttft_p50_ms={np.percentile(ttft_ms, 50):.1f},"
        f"ttft_p99_ms={np.percentile(ttft_ms, 99):.1f},"
        f"admitted={stats.admitted},preempted={stats.preempted}")
    print_fn(
        f"serving,{section}_host,decode_s={eng.decode_seconds:.3f},"
        f"prefill_s={eng.prefill_seconds:.3f},host_s={host_s:.3f},"
        f"wall_s={wall:.3f},dispatches={dispatches},"
        f"dispatches_per_token={dispatches / max(total_tokens, 1):.3f},"
        f"device_steps={eng.decode_device_steps}")
    print_fn(
        f"serving,kv_bytes,int8_paged={bytes_['int8_paged']:.3e},"
        f"dense_f32={bytes_['dense_f32']:.3e},"
        f"ratio={bytes_['int8_paged'] / bytes_['dense_f32']:.3f}")
    rec = {
        "section": section, "streams": n_streams,
        "max_batch": max_batch, "arrival_rate": arrival_rate,
        "decode_horizon": decode_horizon,
        "pages_per_slot": per_slot,
        "steps": step, "tokens": total_tokens,
        "tokens_per_s": round(total_tokens / wall, 1),
        "prefill_tokens": int(eng.prefill_tokens),
        "prefill_tokens_per_s": round(prefill_tps, 1),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 2),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 2),
        "ttft_p50_ms": round(float(np.percentile(ttft_ms, 50)), 2),
        "ttft_p99_ms": round(float(np.percentile(ttft_ms, 99)), 2),
        "admitted": stats.admitted, "preempted": stats.preempted,
        "host_breakdown": {
            "wall_s": round(wall, 4),
            "decode_s": round(eng.decode_seconds, 4),
            "prefill_s": round(eng.prefill_seconds, 4),
            "host_s": round(host_s, 4),
            "decode_dispatches": eng.decode_dispatches,
            "prefill_dispatches": eng.prefill_dispatches,
            "dispatches_per_token": round(
                dispatches / max(total_tokens, 1), 4),
            "device_steps": eng.decode_device_steps,
            "horizon_hist": {str(k): v
                             for k, v in sorted(eng.horizon_hist.items())},
        },
        "kv_bytes": bytes_}
    if records is not None:
        records.append(rec)
    return rec


def run_horizon_sweep(params, cfg, *, n_streams, max_batch, horizons,
                      seed=0, print_fn=print, records: list | None = None,
                      backend="auto", max_new=48, prompt_len=12):
    """Saturated decode-bound cell across fused scan lengths.

    Every stream generates exactly ``max_new`` tokens (a multiple of
    every swept horizon, so macro-steps never straddle a request's
    tail) and all requests are queued upfront, keeping the batch full
    for the whole run: scan-lane waste is structurally zero and the
    horizons differ only in dispatch granularity.  This isolates the
    decode-loop fusion economics the ``--min-horizon-speedup`` CI gate
    rides on; the Poisson ``load`` cells keep measuring admission
    churn, where 4-12-token streams legitimately favour ``h == 1``.
    """
    rng = np.random.default_rng(seed)
    page_size = 16
    per_slot = -(-(prompt_len + max_new + 1) // page_size) + 1
    n_pages = max_batch * per_slot + 1
    for h in horizons:
        eng = _engine(params, cfg, max_batch=max_batch, n_pages=n_pages,
                      backend=backend, page_size=page_size,
                      max_pages_per_slot=per_slot, decode_horizon=h)
        k = 1
        while k <= h:
            eng.run([Request(uid=-1, tokens=np.zeros(prompt_len, np.int32),
                             max_new_tokens=k + 1)])
            k *= 2
        eng.reset_counters()
        reqs = [Request(uid=i,
                        tokens=rng.integers(0, cfg.vocab, prompt_len)
                        .astype(np.int32),
                        max_new_tokens=max_new)
                for i in range(n_streams)]
        t0 = time.perf_counter()
        done = eng.run(reqs)
        wall = time.perf_counter() - t0
        eng.sched.assert_invariants()
        total_tokens = sum(len(r.out) for r in done)
        assert total_tokens == n_streams * max_new
        host_s = max(wall - eng.decode_seconds - eng.prefill_seconds, 0.0)
        dispatches = eng.decode_dispatches + eng.prefill_dispatches
        print_fn(
            f"serving,horizon_sweep,streams={n_streams},"
            f"max_batch={max_batch},decode_horizon={h},"
            f"max_new={max_new},tokens={total_tokens},"
            f"tokens_per_s={total_tokens / wall:.1f},"
            f"decode_s={eng.decode_seconds:.3f},"
            f"prefill_s={eng.prefill_seconds:.3f},host_s={host_s:.3f},"
            f"dispatches={dispatches},"
            f"device_steps={eng.decode_device_steps}")
        if records is not None:
            records.append({
                "section": "horizon_sweep", "streams": n_streams,
                "max_batch": max_batch, "decode_horizon": h,
                "max_new": max_new, "pages_per_slot": per_slot,
                "tokens": total_tokens,
                "tokens_per_s": round(total_tokens / wall, 1),
                "host_breakdown": {
                    "wall_s": round(wall, 4),
                    "decode_s": round(eng.decode_seconds, 4),
                    "prefill_s": round(eng.prefill_seconds, 4),
                    "host_s": round(host_s, 4),
                    "decode_dispatches": eng.decode_dispatches,
                    "prefill_dispatches": eng.prefill_dispatches,
                    "dispatches_per_token": round(
                        dispatches / max(total_tokens, 1), 4),
                    "device_steps": eng.decode_device_steps,
                    "horizon_hist": {
                        str(k): v
                        for k, v in sorted(eng.horizon_hist.items())},
                }})


def run_kernel_blocks(print_fn=print, records: list | None = None):
    """Record the launch geometry the serving engines' Pallas GEMMs will
    resolve per shape class — so a BENCH_serving.json diff shows when a
    tuned cache (or a heuristic change) moved the serving block shapes."""
    table = autotune.resolved_table()
    for cls, cfg in table.items():
        print_fn(f"serving,kernel_blocks,{cls},bm={cfg['block_m']},"
                 f"bn={cfg['block_n']},{cfg['exp_layout']},"
                 f"{cfg['blocks_source']}")
    if records is not None:
        records.append({"section": "kernel_blocks", **table})


def run(print_fn=print, smoke: bool = False, records: list | None = None,
        seed: int = 0, decode_horizon: int = 8):
    cfg = get_smoke("tinyllama-1.1b")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    run_kernel_blocks(print_fn, records)
    run_parity(params, cfg, print_fn, records)
    # The --decode-horizon sweep runs its own saturated decode-bound
    # cell (fixed-length streams, arrivals upfront) — the Poisson load
    # cells below keep measuring admission churn.
    if smoke:  # the CI cell: 64 concurrent streams, oracle numbers
        sweep_cell, horizons = (128, 32), (1, decode_horizon)
        cells = ((64, 64),)
    else:  # the CI cell first (so the committed floor overlaps smoke
           # runs and check_serving_floor can gate them), then hundreds
           # of streams at two concurrency points
        sweep_cell, horizons = (128, 32), (1, 4, 8, 16)
        cells = ((64, 64), (128, 32), (256, 64))
    for n_streams, max_batch in cells:
        run_load(params, cfg, n_streams=n_streams,
                 max_batch=max_batch, arrival_rate=8.0, seed=seed,
                 print_fn=print_fn, records=records,
                 decode_horizon=decode_horizon)
    run_horizon_sweep(params, cfg, n_streams=sweep_cell[0],
                      max_batch=sweep_cell[1],
                      horizons=tuple(dict.fromkeys(horizons)), seed=seed,
                      print_fn=print_fn, records=records)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="parity gate + one 64-stream load cell (CI job)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write machine-readable records "
                         "(e.g. BENCH_serving.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-horizon", type=int, default=8,
                    help="fused decode steps per engine heartbeat for the "
                         "load cells (pow2; the sweep section always "
                         "includes horizon 1 for the speedup baseline)")
    args = ap.parse_args(argv)
    records: list | None = [] if args.json else None
    run(smoke=args.smoke, records=records, seed=args.seed,
        decode_horizon=args.decode_horizon)
    if args.json:
        payload = {
            "benchmark": "serving_bench",
            "smoke": bool(args.smoke),
            "unix_time": int(time.time()),
            "jax_version": jax.__version__,
            "jax_backend": jax.default_backend(),
            "platform": platform.platform(),
            "records": records,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"serving,json -> {args.json} ({len(records)} records)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
