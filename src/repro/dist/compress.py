"""INT8 gradient compression for the DCN ("pod") axis.

Cross-pod gradient reduction is the one collective that crosses the slow
data-center network; quantizing each leaf to INT8 with a per-leaf scale
cuts those bytes 4x.  The trainer composes this inside ``shard_map`` over
"pod" only — ICI-axis reductions stay in autodiff at full precision.
Error feedback (caller-held residual) keeps the accumulated quantized sum
tracking the true sum; see ``tests/test_sharding_roofline.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_grad(g: jax.Array, bits: int = 8):
    """Per-tensor symmetric INT8 codes + float scale for one gradient."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / qmax + 1e-30
    codes = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                     -qmax - 1, qmax).astype(jnp.int8)
    return codes, scale


def dequantize_grad(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def compress_tree_psum(tree, axis_name: str, bits: int = 8):
    """Quantize every leaf to INT8, then average across ``axis_name``.

    The collective moves the INT8 *codes* (all_gather + local
    dequantize-mean), not dequantized fp32 — each pod holds its own
    per-leaf scale, so a direct fp32 psum would forfeit the 4x DCN byte
    saving this module exists for.  Returns ``(tree, info)`` with the
    wire bytes of both paths.  Must run inside ``shard_map`` (or any
    context where ``axis_name`` is bound).
    """
    def f(g):
        codes, scale = quantize_grad(g, bits)
        all_codes = jax.lax.all_gather(codes, axis_name)    # int8 on wire
        all_scales = jax.lax.all_gather(scale, axis_name)   # one fp32/pod
        deq = all_codes.astype(jnp.float32) * all_scales.reshape(
            (-1,) + (1,) * codes.ndim)
        return jnp.mean(deq, axis=0)

    out = jax.tree.map(f, tree)
    n = sum(int(x.size) for x in jax.tree.leaves(tree))
    info = {"int8_bytes": n, "fp32_bytes": 4 * n}
    return out, info
