"""Serving: prefill/decode engine with batched requests, INT8 KV helpers."""
from .engine import (
    Request,
    ServingEngine,
    dequantize_kv,
    quantize_kv,
)

__all__ = ["Request", "ServingEngine", "dequantize_kv", "quantize_kv"]
