"""INT8 KV-cache decode attention (PO2 shift scales) — Pallas kernel.

Served in production as the ``kv_attention`` exec op family
(``repro.exec.execute_kv_attention``: ``oracle`` -> ``int8_kv_attention_ref``,
``pallas`` -> ``int8_kv_attention``), which is how the paged serving
engine's decode reads its cache — ``block_s`` there is the page size, so
the gathered page view always tiles exactly.
"""
from .kernel import int8_kv_attention_kernel
from .ops import cache_bytes, int8_kv_attention, int8_kv_attention_f32
from .ref import (
    dequantize_kv_po2,
    fp_attention_ref,
    int8_kv_attention_ref,
    quantize_kv_po2,
)

__all__ = [
    "cache_bytes", "dequantize_kv_po2", "fp_attention_ref",
    "int8_kv_attention", "int8_kv_attention_f32",
    "int8_kv_attention_kernel", "int8_kv_attention_ref",
    "quantize_kv_po2",
]
