"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``.

Spins up a continuous-batching engine on a (reduced or full) config and
drives a synthetic request stream, reporting per-request outputs and
decode-step throughput.  ``--engine paged`` serves through the paged
INT8 KV cache (``PagedServingEngine``: page-pool scheduler with
mid-decode eviction, attention reads via the ``kv_attention`` exec op
family); the default ``dense`` engine keeps the float reference path.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--engine", choices=("dense", "paged"), default="dense",
                    help="dense float KV slots, or the paged INT8 KV "
                         "cache with the continuous-batching scheduler")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--decode-horizon", type=int, default=8,
                    help="fused decode steps per engine heartbeat (pow2). "
                         "Raise when decode is dispatch-bound; 1 restores "
                         "the per-token heartbeat (tight page pools, "
                         "strict per-token SLO).  Paged engine only.")
    ap.add_argument("--backend", default="auto",
                    help="exec backend for integer ops: auto|oracle|pallas")
    ap.add_argument("--mesh", default=None, metavar="SHAPE",
                    help="serve across a device mesh, e.g. '1x2' "
                         "(data x model) or '2x1x2' (pod x data x model). "
                         "Implies --engine paged --exported; the model "
                         "axis shards INT8 code banks + KV head pools "
                         "(repro.dist.tp).  Off-TPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first.")
    ap.add_argument("--wire", choices=("int8", "fp32"), default="int8",
                    help="collective payload for sharded serving: int8 "
                         "codes (default) or the fp32 parity-debug path")
    ap.add_argument("--exported", action="store_true",
                    help="calibrate + export to INT8 codes and serve "
                         "through the integer kernel path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke
    from repro.models.model import init_lm
    from repro.serving import PagedServingEngine, Request, ServingEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encdec:
        raise SystemExit("enc-dec serving requires encoder inputs; use the "
                         "examples/serve.py driver for seamless")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_smoke_mesh
        shape = tuple(int(s) for s in args.mesh.lower().split("x"))
        axes = (("pod", "data", "model") if len(shape) == 3
                else ("data", "model"))
        mesh = make_smoke_mesh(shape, axes)
        args.engine = "paged"
        args.exported = True
        print(f"[serve] mesh {dict(mesh.shape)} wire={args.wire}")

    if args.exported and (cfg.quant is None or not cfg.quant.enabled):
        # Integer serving needs quantizer state; default to the paper's
        # APSQ preset when the arch config ships without one.
        from repro.core import QuantConfig
        cfg = cfg.with_quant(QuantConfig.apsq(gs=2, n_p=4))
        print(f"[serve] {args.arch} has quant disabled -> "
              f"applying apsq(gs=2, n_p=4) for --exported")
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)

    if args.exported:
        from repro.quant import calibrate_model
        rng_cal = np.random.default_rng(args.seed)
        tok = rng_cal.integers(0, cfg.vocab, size=(2, 32))
        params = calibrate_model(params, cfg, {"tokens": jax.numpy.asarray(
            tok, jax.numpy.int32)})

    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        size=rng.integers(4, 32)),
                    max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]

    if args.engine == "paged":
        n_pages = args.cache_len // args.page_size * args.max_batch + 1
        kw = dict(max_batch=args.max_batch, page_size=args.page_size,
                  n_pages=n_pages, backend=args.backend, mesh=mesh,
                  wire=args.wire, decode_horizon=args.decode_horizon)
        engine = (PagedServingEngine.from_exported(params, cfg, **kw)
                  if args.exported else
                  PagedServingEngine(params, cfg, **kw))
    else:
        if args.exported:
            engine = ServingEngine.from_exported(
                params, cfg, max_batch=args.max_batch,
                cache_len=args.cache_len, backend=args.backend)
        else:
            engine = ServingEngine(params, cfg, max_batch=args.max_batch,
                                   cache_len=args.cache_len,
                                   backend=args.backend)
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt[{len(r.tokens)}] -> {r.out}")


if __name__ == "__main__":
    main()
