"""repro.search: inventory namespace, candidates, Pareto, scoring."""
import random

import jax
import pytest

from repro.configs import canonical_arch, get_config
from repro.core import QuantConfig, QuantState
from repro.energy import AcceleratorConfig
from repro.models.config import ModelConfig
from repro.models.model import init_lm
from repro.quant import QuantPolicy
from repro.roofline import backend_corrected_terms, gemm_analytic_us
from repro.search import (
    SearchSpace,
    accuracy_proxy,
    dominates,
    energy_report,
    energy_specs,
    layer_classes,
    make_eval_batch,
    model_inventory,
    oracle_logits,
    pareto_front,
    quantizable_names,
    roundtrip_report,
)
from repro.search.candidates import mutate, seed_candidates, \
    uniform_baselines
from repro.search.pareto import ScoredCandidate

ACC = AcceleratorConfig()


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(name="tiny", family="dense", n_layers=2, d_model=32,
                n_heads=2, n_kv_heads=1, d_ff=64, vocab=64,
                dtype="float32", scan_layers=False)
    base.update(kw)
    return ModelConfig(**base).validate()


def quant_state_names(params) -> set:
    names = set()

    def walk(tree):
        if isinstance(tree, QuantState):
            names.add(tree.name)
        elif isinstance(tree, dict):
            for v in tree.values():
                walk(v)
    walk(params)
    return names


# ---------------------------------------------------------------------------
# Inventory: the shared layer namespace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {},                                                      # dense swiglu
    {"block_pattern": ("attn", "local"), "n_layers": 3},     # rem layer
    {"mlp": "moe", "n_experts": 2, "top_k": 1},              # MoE
    {"block_pattern": ("rwkv",), "mlp": "rwkv_cm"},          # RWKV
    {"block_pattern": ("rglru",), "d_rnn": 32},              # RG-LRU
    {"encdec": True, "n_enc_layers": 2},                     # enc-dec
])
def test_inventory_names_match_init_lm(kw):
    """Every QuantState name init_lm creates appears in the inventory
    (and vice versa — 'head' exists only for tied embeddings)."""
    cfg = tiny_cfg(**kw).with_quant(
        QuantPolicy.uniform(QuantConfig.apsq(gs=2, n_p=4)))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    init_names = quant_state_names(params)
    inv_names = set(quantizable_names(model_inventory(cfg, 64)))
    assert init_names, "no quantized linears built?"
    assert inv_names - {"head"} == init_names


def test_inventory_tied_head_in_namespace():
    inv = model_inventory(tiny_cfg(tie_embeddings=True), 64)
    assert "head" in quantizable_names(inv)
    inv = model_inventory(tiny_cfg(), 64)
    assert "head" not in quantizable_names(inv)
    # the untied head GEMM still contributes energy, anonymously
    assert any(e.shape.name == "head" and not e.quantizable for e in inv)


def test_inventory_scan_stack_folds_repeats():
    """22 scan-stacked layers share names -> repeat carries the count."""
    cfg = get_config("tinyllama-1.1b")
    inv = model_inventory(cfg, 4096)
    wq = next(e for e in inv if e.shape.name == "unit.0.mix.wq")
    assert wq.shape.repeat == cfg.n_layers
    assert wq.shape.c_i == cfg.d_model


def test_inventory_decode_stage_single_token():
    cfg = tiny_cfg()
    inv = model_inventory(cfg, 128, stage="decode")
    wq = next(e for e in inv if e.shape.name.endswith("mix.wq"))
    assert wq.shape.tokens == 1
    scores = next(e for e in inv if e.shape.name.endswith("mix.scores"))
    assert scores.shape.c_o == 128          # attends to the KV history


def test_layer_classes_grouping():
    classes = layer_classes(model_inventory(tiny_cfg(), 64))
    assert set(classes) == {"*.mix.*", "*.ffn.*"}
    assert "unit.0.mix.wq" in classes["*.mix.*"]
    assert "unit.0.ffn.wo" in classes["*.ffn.*"]
    classes = layer_classes(
        model_inventory(tiny_cfg(block_pattern=("attn", "local"),
                                 n_layers=3), 64))
    assert "rem.*" in classes
    # precedence: specific classes MUST precede generic ones — candidate
    # policies are first-match-wins, so '*.mix.*' before 'rem.*' would
    # silently shadow the remainder-layer knob
    order = list(classes)
    assert order.index("rem.*") < order.index("*.mix.*")
    from repro.search.candidates import Candidate
    cand = Candidate(name="t", assignment=tuple(
        (p, ("w8a8",) if p == "rem.*" else ("apsq", 2, 4))
        for p in classes))
    assert cand.policy().resolve("rem.0.mix.wq") == QuantConfig.w8a8()


def test_energy_specs_resolution():
    inv = model_inventory(tiny_cfg(), 64)
    policy = QuantPolicy.of(
        ("*.ffn.*", QuantConfig.apsq(gs=2, n_p=4)),
        default=QuantConfig.w8a8())
    specs = {s.layer.name: s for s in energy_specs(inv, policy, ACC)}
    ffn = specs["unit.0.ffn.wi"]
    assert ffn.psum_bits == 8 and ffn.gs == 2
    assert ffn.n_p >= -(-32 // ACC.P_ci)     # hardware floor on tiling
    mix = specs["unit.0.mix.wq"]
    assert mix.psum_bits == 32 and mix.n_p is None
    # PSQ keeps every tile live
    psq = QuantPolicy.uniform(QuantConfig.psq(n_p=4))
    s = {x.layer.name: x for x in energy_specs(inv, psq, ACC)}
    assert s["unit.0.ffn.wi"].gs == s["unit.0.ffn.wi"].n_p


# ---------------------------------------------------------------------------
# Candidates + Pareto
# ---------------------------------------------------------------------------

def test_candidates_and_mutation():
    classes = layer_classes(model_inventory(tiny_cfg(), 64))
    space = SearchSpace()
    bases = uniform_baselines(classes, space)
    assert all(not c.heterogeneous for c in bases)
    assert any(c.name == "uniform_w8a8" for c in bases)
    seeds = seed_candidates(classes, space)
    assert seeds and all(c.heterogeneous for c in seeds)
    # policies lower to resolvable QuantPolicy rules
    pol = seeds[0].policy()
    assert pol.resolve("unit.0.ffn.wi") is not None
    rng = random.Random(0)
    child = mutate(seeds[0], rng, space)
    diff = [i for i, (a, b) in enumerate(zip(seeds[0].assignment,
                                             child.assignment)) if a != b]
    assert len(diff) == 1                     # exactly one local move


def test_policy_sweep_and_fixed_candidates():
    """The dryrun --quant-policy sweep resolution is the shared helper,
    and presets enter the search as unmutatable fixed candidates."""
    from repro.search import FixedCandidate, policy_sweep

    sweep = dict(policy_sweep("all"))
    assert "policy_mix2_ffn4" in sweep
    assert dict(policy_sweep("ffn_only"))  # single preset
    with pytest.raises(KeyError):
        policy_sweep("nonesuch")
    cand = FixedCandidate(name="policy_mix2_ffn4",
                          fixed_policy=sweep["policy_mix2_ffn4"])
    assert cand.heterogeneous
    assert cand.policy().resolve("unit.0.ffn.wi").psum.mode == "apsq"
    assert cand.describe()["origin"] == "preset"


def test_pareto_front_dominance():
    def pt(name, e, err, het=True):
        cand = seed_candidates(
            layer_classes(model_inventory(tiny_cfg(), 64)),
            SearchSpace())[0]
        cand = type(cand)(name=name, assignment=cand.assignment)
        return ScoredCandidate(candidate=cand, energy_j=e, error=err)

    a = pt("a", 1.0, 0.5)
    b = pt("b", 2.0, 0.3)
    c = pt("c", 2.5, 0.4)    # dominated by b
    d = pt("d", 1.0, 0.5)    # duplicate of a
    assert dominates(b, c) and not dominates(a, b)
    front = pareto_front([a, b, c, d])
    assert [p.candidate.name for p in front] == ["a", "b"]


# ---------------------------------------------------------------------------
# Scoring axes + round trip (integration, CPU-tiny)
# ---------------------------------------------------------------------------

def test_energy_report_policy_ordering():
    cfg = get_config("tinyllama-1.1b")
    inv = model_inventory(cfg, 4096)
    w8a8 = energy_report(cfg, QuantPolicy.uniform(QuantConfig.w8a8()),
                         inventory=inv)
    apsq = energy_report(cfg, QuantPolicy.uniform(QuantConfig.apsq()),
                         inventory=inv)
    het = energy_report(cfg, QuantPolicy.of(
        ("*.ffn.*", QuantConfig.apsq()), default=QuantConfig.w8a8()),
        inventory=inv)
    assert apsq["energy_j"] < het["energy_j"] < w8a8["energy_j"]
    assert apsq["saving"] > 0.2               # paper-band PSUM saving
    assert w8a8["saving"] == pytest.approx(0.0)


def test_accuracy_proxy_and_roundtrip():
    """More aggressive PSUM quantization -> larger proxy error, and the
    searched policy serves through calibrate -> export -> pallas."""
    cfg = tiny_cfg()
    batch = make_eval_batch(cfg, 1, 16)
    ref = oracle_logits(cfg, batch)
    w8a8 = accuracy_proxy(cfg, QuantPolicy.uniform(QuantConfig.w8a8()),
                          batch, ref)
    apsq = accuracy_proxy(
        cfg, QuantPolicy.uniform(QuantConfig.apsq(gs=1, n_p=8)), batch, ref)
    assert 0 < w8a8["error"] < apsq["error"]
    assert 0 <= w8a8["top1_agreement"] <= 1

    policy = QuantPolicy.of(("*.ffn.*", QuantConfig.apsq(gs=2, n_p=4)),
                            default=QuantConfig.w8a8())
    rt = roundtrip_report(cfg, policy, batch, max_new_tokens=4)
    assert rt["ok"]
    assert rt["gemm_parity"]["bit_equal"]
    assert rt["serving_parity"]
    assert rt["decode"]["oracle"] == rt["decode"]["pallas"]


# ---------------------------------------------------------------------------
# Satellites living nearby: arch aliases + backend-aware roofline
# ---------------------------------------------------------------------------

def test_canonical_arch_accepts_module_spelling():
    assert canonical_arch("tinyllama_1_1b") == "tinyllama-1.1b"
    assert canonical_arch("tinyllama-1.1b") == "tinyllama-1.1b"
    with pytest.raises(KeyError):
        canonical_arch("nonesuch")


def test_backend_corrected_terms():
    terms = {"compute_s": 1e-3, "memory_s": 2e-3, "collective_s": 0.0,
             "dcn_s": 0.0}
    parity = {"shape": [8, 512, 512], "pallas_us": 100.0,
              "oracle_us": 50.0}
    corr = backend_corrected_terms(terms, parity)
    analytic = gemm_analytic_us(8, 512, 512)
    assert corr["probe_analytic_us"] == pytest.approx(analytic)
    assert corr["correction"] == pytest.approx(100.0 / analytic)
    assert corr["corrected_compute_s"] == pytest.approx(
        1e-3 * corr["correction"])
    assert corr["corrected_bound_s"] >= corr["corrected_compute_s"]
    assert backend_corrected_terms(terms, {"skipped": "x"}) == {}
