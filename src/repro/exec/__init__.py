"""Execution-backend layer: how a deployed integer GEMM is computed.

One registry (``oracle`` | ``pallas`` | ``auto``) behind one entry point,
``execute_gemm(deployed_layer, x)`` — see ``backends.py`` for the design.
"""
from .backends import (
    AutoBackend,
    DEFAULT_BACKEND,
    ExecBackend,
    OracleBackend,
    PallasBackend,
    available_backends,
    backend_parity_check,
    execute_expert_gemm,
    execute_gemm,
    get_backend,
    quantize_activations,
    register_backend,
)

__all__ = [
    "AutoBackend", "DEFAULT_BACKEND", "ExecBackend", "OracleBackend",
    "PallasBackend", "available_backends", "backend_parity_check",
    "execute_expert_gemm", "execute_gemm", "get_backend",
    "quantize_activations", "register_backend",
]
