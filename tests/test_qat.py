"""QAT integration: model-wide calibration taps, distillation loss."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantConfig
from repro.models.config import ModelConfig
from repro.models.model import forward, init_lm
from repro.quant import calibrate_model, distill_loss, make_distill_loss_fn

CFG = ModelConfig(name="qat", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  dtype="float32", scan_layers=False,
                  quant=QuantConfig.apsq(gs=2, n_p=4))


def test_calibrate_model_updates_scales():
    params = init_lm(jax.random.PRNGKey(0), CFG)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    before = [np.asarray(l["qp"]["ap"]) for l in _linears(params)]
    calibrated = calibrate_model(params, CFG, {"tokens": tok})
    after = [np.asarray(l["qp"]["ap"]) for l in _linears(calibrated)]
    changed = sum(not np.allclose(b, a) for b, a in zip(before, after))
    assert changed >= len(before) // 2, f"only {changed} scales updated"
    # calibrated model still runs and improves (or matches) quant error
    lg = forward(calibrated, CFG, tok)
    assert not bool(jnp.any(jnp.isnan(lg)))


def _linears(params):
    out = []

    def walk(t):
        if isinstance(t, dict):
            if "w" in t and "qp" in t and "ap" in t["qp"]:
                out.append(t)
            for k, v in t.items():
                if k not in ("w", "qp"):
                    walk(v)
    walk(params)
    return out


def test_distill_loss_zero_when_matched():
    logits = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 32))
    labels = jnp.argmax(logits, -1)
    l_same = distill_loss(logits, logits, labels, alpha=1.0)
    assert float(l_same) < 1e-5  # pure KL of identical distributions


def test_distill_loss_fn_grads():
    teacher_cfg = ModelConfig(name="t", family="dense", n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=2,
                              d_ff=128, vocab=256, dtype="float32")
    t_params = init_lm(jax.random.PRNGKey(3), teacher_cfg)
    s_params = init_lm(jax.random.PRNGKey(4), CFG)
    fn = make_distill_loss_fn(CFG, teacher_cfg, t_params)
    tok = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, 256)
    batch = {"tokens": tok, "labels": tok}
    loss, g = jax.value_and_grad(fn)(s_params, batch)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))
