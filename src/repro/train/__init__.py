"""Training: sharded train_step factory + fault-tolerant host loop."""
from .trainer import (
    StragglerWatchdog,
    TrainConfig,
    Trainer,
    make_grads_fn,
    make_loss_fn,
    make_train_step,
    shardings_for_training,
)

__all__ = ["StragglerWatchdog", "TrainConfig", "Trainer", "make_grads_fn",
           "make_loss_fn", "make_train_step", "shardings_for_training"]
