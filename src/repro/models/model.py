"""Full-model assembly: decoder-only LMs, encoder-decoder, modality stubs.

An architecture is a repeating ``block_pattern`` of time-mix kinds
(attn | local | rwkv | rglru) with one channel mix (swiglu | gelu | moe |
rwkv_cm).  Full layers = ``n_units`` repeats of the pattern (stacked params,
``lax.scan`` over units, optional remat) + ``n_rem`` unstacked remainder
layers.  Every GEMM goes through ``dense`` so the paper's APSQ applies to
any architecture via ``cfg.quant``.

Three entry points per model:
  * ``forward``        — training / one-shot prefill; returns logits (and,
    when ``collect_cache`` is set, per-layer decode states for serving).
  * ``decode_step``    — one token with per-layer caches/recurrent states.
  * ``init_lm`` / ``lm_specs`` / ``init_decode_state`` — params, logical
    sharding specs (same tree), fresh decode state.

Modality stubs (assignment rule): ``[audio]``/``[vlm]`` archs take
precomputed frame/patch embeddings as inputs; there is no conv/ViT stack.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import QuantConfig
from .common import (
    Params,
    apply_norm,
    dense,
    init_embedding,
    init_linear,
    init_mlp,
    init_norm,
    apply_mlp,
    embedding_specs,
    linear_specs,
    mlp_specs,
    norm_specs,
)
from .attention import (
    attention_block,
    attention_specs,
    init_attention,
)
from .moe import init_moe, moe_ffn, moe_ffn_sharded, moe_specs
from .rwkv import (
    init_rwkv_channel_mix,
    init_rwkv_state,
    init_rwkv_time_mix,
    rwkv_channel_mix,
    rwkv_channel_mix_specs,
    rwkv_time_mix,
    rwkv_time_mix_specs,
)
from .rglru import (
    init_rglru_block,
    init_rglru_state,
    rglru_block,
    rglru_block_specs,
)
from .config import ModelConfig

SPEC_LEAF = lambda x: isinstance(x, tuple)  # logical-axis tuples are leaves


def tmap(f, *trees):
    """tree.map with logical-axis tuples treated as leaves."""
    return jax.tree.map(f, *trees, is_leaf=SPEC_LEAF)


# ---------------------------------------------------------------------------
# One layer (time mix + channel mix, pre-norm residual)
# ---------------------------------------------------------------------------

def _init_ffn(key, cfg: ModelConfig, quant, name: str = ""):
    if cfg.mlp == "moe":
        return init_moe(key, cfg.d_model, cfg.d_ff, cfg.n_experts,
                        cfg.top_k, cfg.jdtype, quant=quant, name=name)
    if cfg.mlp == "rwkv_cm":
        return init_rwkv_channel_mix(key, cfg.d_model, cfg.d_ff, cfg.jdtype,
                                     quant=quant, name=name)
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.jdtype, kind=cfg.mlp,
                    quant=quant, name=name)


def _ffn_specs(cfg: ModelConfig, quant, name: str = ""):
    if cfg.mlp == "moe":
        return moe_specs(quant, name)
    if cfg.mlp == "rwkv_cm":
        return rwkv_channel_mix_specs(quant, name)
    return mlp_specs(cfg.mlp, quant, name)


def init_layer(key, cfg: ModelConfig, kind: str, cross: bool = False,
               name: str = "unit.0") -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    quant = cfg.policy
    p: Params = {"ln1": init_norm(cfg.d_model, cfg.jdtype, cfg.norm),
                 "ln2": init_norm(cfg.d_model, cfg.jdtype, cfg.norm)}
    if kind in ("attn", "local"):
        p["mix"] = init_attention(k1, cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.hd, cfg.jdtype,
                                  quant=quant, name=f"{name}.mix")
    elif kind == "rwkv":
        p["mix"] = init_rwkv_time_mix(k1, cfg.d_model, cfg.n_heads, cfg.hd,
                                      cfg.jdtype, quant=quant,
                                      name=f"{name}.mix")
    elif kind == "rglru":
        p["mix"] = init_rglru_block(k1, cfg.d_model, cfg.d_rnn, cfg.jdtype,
                                    quant=quant, name=f"{name}.mix")
    else:
        raise ValueError(kind)
    p["ffn"] = _init_ffn(k2, cfg, quant, name=f"{name}.ffn")
    if cross:
        p["lnx"] = init_norm(cfg.d_model, cfg.jdtype, cfg.norm)
        p["xattn"] = init_attention(k3, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.hd, cfg.jdtype,
                                    quant=quant, name=f"{name}.xattn")
    return p


def layer_specs(cfg: ModelConfig, kind: str, cross: bool = False,
                name: str = "unit.0") -> Params:
    quant = cfg.policy
    s: Params = {"ln1": norm_specs(cfg.norm), "ln2": norm_specs(cfg.norm)}
    if kind in ("attn", "local"):
        s["mix"] = attention_specs(quant, f"{name}.mix")
    elif kind == "rwkv":
        s["mix"] = rwkv_time_mix_specs(quant, f"{name}.mix")
    elif kind == "rglru":
        s["mix"] = rglru_block_specs(quant, f"{name}.mix")
    s["ffn"] = _ffn_specs(cfg, quant, name=f"{name}.ffn")
    if cross:
        s["lnx"] = norm_specs(cfg.norm)
        s["xattn"] = attention_specs(quant, f"{name}.xattn")
    return s


def init_layer_state(cfg: ModelConfig, kind: str, batch: int,
                     cache_len: int) -> Params:
    """Fresh decode state for one layer of the given kind."""
    if kind == "attn":
        shape = (batch, cache_len, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, cfg.jdtype),
                "v": jnp.zeros(shape, cfg.jdtype)}
    if kind == "local":
        shape = (batch, min(cfg.local_window, cache_len),
                 cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, cfg.jdtype),
                "v": jnp.zeros(shape, cfg.jdtype)}
    if kind == "rwkv":
        return init_rwkv_state(batch, cfg.d_model, cfg.n_heads, cfg.hd,
                               dtype=cfg.jdtype)
    if kind == "rglru":
        return {"rec": init_rglru_state(batch, cfg.d_rnn, dtype=cfg.jdtype)}
    raise ValueError(kind)


def apply_layer(
    p: Params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    kind: str,
    mesh=None,
    state: Params | None = None,
    pos: jax.Array | int = 0,
    enc_out: jax.Array | None = None,
    causal: bool = True,
    tap: list | None = None,
    backend=None,
    page_table=None,
):
    """One pre-norm block.  ``state`` not None => decode (single token).

    Returns (x, new_state); new_state is None when training without cache.
    ``tap`` is the calibration capture list, threaded down to every
    quantized linear (``repro.core.TapRecord`` per eager invocation).
    ``backend`` selects the integer execution backend (``repro.exec``)
    for deployed params and reaches every projection GEMM in the block.
    ``page_table`` ([B, n_max] physical page ids) switches attention
    layers whose state is a paged INT8 KV cache onto the paged decode
    path (``pos`` is then a per-slot [B] vector).
    """
    # (§Perf it4, refuted: an explicit seq-shard constraint on the
    # residual stream added reshards — GSPMD already propagates SP from
    # the ddlerp/rglru hints.  Left unconstrained.)
    quant = cfg.quant if cfg.quant.enabled else None
    h = apply_norm(p["ln1"], x, cfg.norm)
    new_state: Params = {}

    if kind in ("attn", "local"):
        window = cfg.local_window if kind == "local" else None
        if state is not None and "k_pages" in state:
            cache = state  # paged INT8 pools + running exponents
        elif state is not None:
            cache = {"k": state["k"], "v": state["v"]}
        else:
            cache = None
        out, kv = attention_block(
            p["mix"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_fraction=cfg.rope_fraction,
            rope_theta=cfg.rope_theta, causal=causal, window=window,
            softcap=cfg.softcap, quant=quant, cache=cache, pos=pos,
            mesh=mesh, tap=tap, backend=backend, page_table=page_table)
        new_state = kv
    elif kind == "rwkv":
        # Chunked paged prefill must stay bit-identical to the per-token
        # scan; the chunk-parallel WKV reorders fp32 accumulation, so
        # force the sequential impl whenever a multi-token chunk runs
        # against paged serving state.
        exact = page_table is not None and x.shape[1] > 1
        out, tm_state = rwkv_time_mix(
            p["mix"], h, n_heads=cfg.n_heads, head_dim=cfg.hd, quant=quant,
            impl="scan" if exact else cfg.wkv_impl,
            wkv_chunk=cfg.wkv_chunk, mesh=mesh,
            state=state["tm"] if state is not None else None, tap=tap,
            backend=backend)
        new_state = {"tm": tm_state}
    elif kind == "rglru":
        out, rec_state = rglru_block(
            p["mix"], h, quant=quant, mesh=mesh,
            state=state["rec"] if state is not None else None, tap=tap,
            backend=backend,
            exact_scan=page_table is not None and x.shape[1] > 1)
        new_state = {"rec": rec_state}
    else:
        raise ValueError(kind)
    x = x + out

    if "xattn" in p and enc_out is not None:
        hx = apply_norm(p["lnx"], x, cfg.norm)
        outx, _ = attention_block(
            p["xattn"], hx, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd, quant=quant, xkv=enc_out, use_rope=False,
            mesh=mesh, tap=tap, backend=backend)
        x = x + outx

    h2 = apply_norm(p["ln2"], x, cfg.norm)
    if cfg.mlp == "moe":
        from repro.core import DeployedQuantState
        deployed_moe = isinstance(p["ffn"].get("qp_wi"), DeployedQuantState)
        if mesh is not None and not deployed_moe:
            y = moe_ffn_sharded(p["ffn"], h2, mesh=mesh,
                                n_experts=cfg.n_experts, top_k=cfg.top_k,
                                capacity_factor=cfg.capacity_factor,
                                quant=quant, backend=backend)
        else:
            # Deployed expert banks: EP lives INSIDE the backend
            # (``ShardedBackend.int_expert_gemm`` shard_maps the expert
            # axis and gathers outputs as INT8 codes), so the pure path
            # is the right wrapper — ``moe_ffn_sharded``'s fp32 psum
            # combine would both double-wrap shard_map and lose the
            # int8-on-the-wire saving.
            y = moe_ffn(p["ffn"], h2, n_experts=cfg.n_experts,
                        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                        quant=quant, tap=tap, backend=backend)
    elif cfg.mlp == "rwkv_cm":
        y, cm_state = rwkv_channel_mix(
            p["ffn"], h2, quant=quant, mesh=mesh,
            state=state["cm"] if (state is not None and "cm" in state)
            else None, tap=tap, backend=backend)
        if state is not None:
            new_state["cm"] = cm_state
    else:
        y = apply_mlp(p["ffn"], h2, kind=cfg.mlp, quant=quant, tap=tap,
                      backend=backend)
    x = x + y
    # RWKV layers always carry channel-mix shift state in decode.
    if kind == "rwkv" and state is not None and "cm" not in new_state:
        new_state["cm"] = {"shift": h2[:, -1:]}
    return x, new_state


# ---------------------------------------------------------------------------
# Units (one repeat of block_pattern) — scan-over-units with stacked params
# ---------------------------------------------------------------------------

def init_unit(key, cfg: ModelConfig, cross: bool = False,
              name: str = "unit") -> Params:
    keys = jax.random.split(key, len(cfg.block_pattern))
    return {str(i): init_layer(k, cfg, kind, cross=cross, name=f"{name}.{i}")
            for i, (k, kind) in enumerate(zip(keys, cfg.block_pattern))}


def unit_specs(cfg: ModelConfig, cross: bool = False,
               name: str = "unit") -> Params:
    return {str(i): layer_specs(cfg, kind, cross=cross, name=f"{name}.{i}")
            for i, kind in enumerate(cfg.block_pattern)}


def init_unit_state(cfg: ModelConfig, batch: int, cache_len: int) -> Params:
    return {str(i): init_layer_state(cfg, kind, batch, cache_len)
            for i, kind in enumerate(cfg.block_pattern)}


def apply_unit(p: Params, x, *, cfg: ModelConfig, mesh=None, state=None,
               pos=0, enc_out=None, causal=True, tap: list | None = None,
               backend=None, page_table=None):
    new_state = {}
    for i, kind in enumerate(cfg.block_pattern):
        x, s = apply_layer(
            p[str(i)], x, cfg=cfg, kind=kind, mesh=mesh,
            state=state[str(i)] if state is not None else None,
            pos=pos, enc_out=enc_out, causal=causal, tap=tap,
            backend=backend, page_table=page_table)
        new_state[str(i)] = s
    return x, new_state


def _stack_init(key, n: int, init_fn):
    """vmap an init function over ``n`` split keys -> stacked params."""
    if n == 0:
        return None
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def stack_specs(spec_tree: Params) -> Params:
    """Prepend the 'layers' logical axis to every leaf (scan-stacked)."""
    return tmap(lambda t: ("layers",) + tuple(t), spec_tree)


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> Params:
    cfg.validate()
    ks = jax.random.split(key, 8)
    p: Params = {"embed": init_embedding(ks[0], cfg.vocab, cfg.d_model,
                                         cfg.jdtype)}
    cross = cfg.encdec
    if cfg.scan_layers:
        p["units"] = _stack_init(ks[1], cfg.n_units,
                                 lambda k: init_unit(k, cfg, cross=cross))
    else:  # unstacked: python-unrolled units (tiny models, eager passes)
        uk = jax.random.split(ks[1], max(cfg.n_units, 1))
        p["units"] = {f"u{i}": init_unit(uk[i], cfg, cross=cross)
                      for i in range(cfg.n_units)}
    if cfg.n_rem:
        rk = jax.random.split(ks[2], cfg.n_rem)
        p["rem"] = {str(i): init_layer(rk[i], cfg, cfg.block_pattern[i],
                                       cross=cross, name=f"rem.{i}")
                    for i in range(cfg.n_rem)}
    p["final_norm"] = init_norm(cfg.d_model, cfg.jdtype, cfg.norm)
    if not cfg.tie_embeddings:
        p["head"] = init_linear(ks[3], (cfg.d_model, cfg.vocab), cfg.jdtype)
    if cfg.encdec:
        enc_cfg = dataclasses.replace(cfg, encdec=False)
        p["encoder"] = {
            "units": _stack_init(
                ks[4], cfg.n_enc_layers // len(cfg.block_pattern),
                lambda k: init_unit(k, enc_cfg, name="encoder.unit")),
            "final_norm": init_norm(cfg.d_model, cfg.jdtype, cfg.norm),
        }
    if cfg.frontend == "vision":
        # Stub projection from provided patch embeddings to d_model.
        p["frontend_proj"] = init_linear(ks[5], (cfg.d_model, cfg.d_model),
                                         cfg.jdtype)
    return p


def lm_specs(cfg: ModelConfig) -> Params:
    s: Params = {"embed": embedding_specs()}
    cross = cfg.encdec
    if cfg.scan_layers:
        s["units"] = stack_specs(unit_specs(cfg, cross=cross))
    else:
        s["units"] = {f"u{i}": unit_specs(cfg, cross=cross)
                      for i in range(cfg.n_units)}
    if cfg.n_rem:
        s["rem"] = {str(i): layer_specs(cfg, cfg.block_pattern[i],
                                        cross=cross, name=f"rem.{i}")
                    for i in range(cfg.n_rem)}
    s["final_norm"] = norm_specs(cfg.norm)
    if not cfg.tie_embeddings:
        s["head"] = linear_specs(("embed", "vocab"))
    if cfg.encdec:
        s["encoder"] = {"units": stack_specs(unit_specs(
                            cfg, name="encoder.unit")),
                        "final_norm": norm_specs(cfg.norm)}
    if cfg.frontend == "vision":
        s["frontend_proj"] = linear_specs(("embed", "embed_out"))
    return s


def _remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_units(params_units, x, *, cfg: ModelConfig, mesh, pos, enc_out,
                causal, tap: list | None = None, backend=None):
    if params_units is None:
        return x

    if not cfg.scan_layers:  # unstacked dict (tiny models, eager passes)
        for i in range(len(params_units)):
            x, _ = apply_unit(params_units[f"u{i}"], x, cfg=cfg, mesh=mesh,
                              pos=pos, enc_out=enc_out, causal=causal,
                              tap=tap, backend=backend)
        return x

    # The scan body traces, so the capture tap cannot see its linears —
    # ``repro.quant.calibrate_model`` slices the stacked params and runs
    # per-unit eager passes instead.
    def body(carry, unit_p):
        y, _ = apply_unit(unit_p, carry, cfg=cfg, mesh=mesh, pos=pos,
                          enc_out=enc_out, causal=causal, backend=backend)
        return y, ()

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params_units)
    return x


def embed_inputs(p: Params, cfg: ModelConfig, tokens: jax.Array | None,
                 embeds: jax.Array | None = None) -> jax.Array:
    """Token embedding + optional modality-stub embeddings.

    vision: ``embeds`` [B, n_img, d] are projected and prepended.
    audio (encdec): encoder consumes ``embeds`` directly; decoder uses
    ``tokens`` only — handled by ``forward``.
    """
    parts = []
    if embeds is not None and cfg.frontend == "vision":
        fe = dense(p["frontend_proj"], embeds.astype(cfg.jdtype), None)
        parts.append(fe)
    if tokens is not None:
        parts.append(jnp.take(p["embed"]["table"], tokens, axis=0))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    return x


def encode(p: Params, cfg: ModelConfig, enc_embeds: jax.Array,
           mesh=None, backend=None) -> jax.Array:
    """Encoder stack over precomputed frame embeddings (audio stub)."""
    x = enc_embeds.astype(cfg.jdtype)
    enc_cfg = dataclasses.replace(cfg, encdec=False, scan_layers=True)
    x = _scan_units(p["encoder"]["units"], x, cfg=enc_cfg, mesh=mesh, pos=0,
                    enc_out=None, causal=False, backend=backend)
    return apply_norm(p["encoder"]["final_norm"], x, cfg.norm)


def logits_from_hidden(p: Params, cfg: ModelConfig, x: jax.Array,
                       mesh=None, backend=None):
    from repro.core import (DeployedQuantState, QuantState, quant_dense,
                            tied_head_weight)
    from .common import act_spec, shard_hint
    x = apply_norm(p["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        # The tied head GEMM is quantizable like any projection: a
        # ``qp_head`` state appears after ``calibrate_model`` (fake-quant
        # QAT view over ``tied_head_weight(table)``) and
        # ``export_quantized`` deploys it as INT8 codes + shift exponents
        # routed through the exec backend.
        qp_head = p["embed"].get("qp_head")
        if isinstance(qp_head, DeployedQuantState):
            logits = quant_dense(x, None, qp_head, backend=backend)
        elif isinstance(qp_head, QuantState):
            logits = quant_dense(x, tied_head_weight(p["embed"]["table"]),
                                 qp_head)
        else:
            logits = jnp.einsum("bsd,vd->bsv", x, p["embed"]["table"])
    else:
        logits = dense(p["head"], x, None, backend=backend)
    return shard_hint(logits, act_spec(mesh, x.shape[0], feat=cfg.vocab))


def forward(
    p: Params,
    cfg: ModelConfig,
    tokens: jax.Array | None,
    *,
    embeds: jax.Array | None = None,
    enc_embeds: jax.Array | None = None,
    mesh=None,
    pos: jax.Array | int = 0,
    tap: list | None = None,
    backend=None,
) -> jax.Array:
    """Training / one-shot prefill forward; returns logits [B, S_out, V].

    ``embeds``     — vision patch embeddings (prepended to tokens).
    ``enc_embeds`` — audio frame embeddings for the encoder (encdec only).
    ``tap``        — calibration capture list (reaches every linear only
    when ``cfg.scan_layers`` is False; ``calibrate_model`` handles the
    scanned case by per-unit eager passes).
    ``backend``    — integer execution backend for deployed params
    (``repro.exec``: "oracle" | "pallas" | "auto").
    """
    enc_out = None
    if cfg.encdec:
        assert enc_embeds is not None, "enc-dec model needs enc_embeds"
        enc_out = encode(p, cfg, enc_embeds, mesh=mesh, backend=backend)
    x = embed_inputs(p, cfg, tokens, embeds)
    x = _scan_units(p["units"], x, cfg=cfg, mesh=mesh, pos=pos,
                    enc_out=enc_out, causal=True, tap=tap, backend=backend)
    for i in range(cfg.n_rem):
        x, _ = apply_layer(p["rem"][str(i)], x, cfg=cfg,
                           kind=cfg.block_pattern[i], mesh=mesh, pos=pos,
                           enc_out=enc_out, tap=tap, backend=backend)
    return logits_from_hidden(p, cfg, x, mesh, backend=backend)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int) -> Params:
    """Stacked (per-unit) + remainder decode state for the whole model."""
    state: Params = {}
    if cfg.n_units:
        unit_state = init_unit_state(cfg, batch, cache_len)
        if cfg.scan_layers:
            state["units"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_units,) + a.shape),
                unit_state)
        else:
            state["units"] = {
                f"u{i}": init_unit_state(cfg, batch, cache_len)
                for i in range(cfg.n_units)}
    for i in range(cfg.n_rem):
        state[f"rem{i}"] = init_layer_state(cfg, cfg.block_pattern[i], batch,
                                            cache_len)
    return state


def decode_state_specs(cfg: ModelConfig) -> Params:
    """Logical axes for the decode state (cache sharding)."""
    def kv_spec():
        return {"k": ("batch", None, "kvheads_cache", None),
                "v": ("batch", None, "kvheads_cache", None)}

    def layer_state_spec(kind):
        if kind in ("attn", "local"):
            return kv_spec()
        if kind == "rwkv":
            return {"tm": {"shift": ("batch", None, None),
                           "wkv": ("batch", "heads", None, None)},
                    "cm": {"shift": ("batch", None, None)}}
        if kind == "rglru":
            return {"rec": {"h": ("batch", "rnn"),
                            "conv": ("batch", None, "rnn")}}
        raise ValueError(kind)

    state: Params = {}
    if cfg.n_units:
        unit = {str(i): layer_state_spec(k)
                for i, k in enumerate(cfg.block_pattern)}
        state["units"] = stack_specs(unit)
    for i in range(cfg.n_rem):
        state[f"rem{i}"] = layer_state_spec(cfg.block_pattern[i])
    return state


def decode_step(
    p: Params,
    cfg: ModelConfig,
    state: Params,
    token: jax.Array,
    pos: jax.Array,
    *,
    enc_out: jax.Array | None = None,
    mesh=None,
    backend=None,
):
    """One decode step.  token: [B, 1] int32; pos: scalar int32 (position of
    this token).  Returns (logits [B, 1, V], new_state).  ``backend``
    selects the integer execution backend for deployed params — the
    decode hot loop runs the Pallas kernel when it resolves to "pallas"."""
    x = jnp.take(p["embed"]["table"], token, axis=0)

    new_state = dict(state)
    if cfg.n_units:
        if cfg.scan_layers:
            def body(carry, xs):
                unit_p, unit_s = xs
                y, s = apply_unit(unit_p, carry, cfg=cfg, mesh=mesh,
                                  state=unit_s, pos=pos, enc_out=enc_out,
                                  backend=backend)
                return y, s

            x, new_units = jax.lax.scan(body, x, (p["units"], state["units"]))
            new_state["units"] = new_units
        else:
            new_units = {}
            for i in range(cfg.n_units):
                x, s = apply_unit(p["units"][f"u{i}"], x, cfg=cfg, mesh=mesh,
                                  state=state["units"][f"u{i}"], pos=pos,
                                  enc_out=enc_out, backend=backend)
                new_units[f"u{i}"] = s
            new_state["units"] = new_units
    for i in range(cfg.n_rem):
        x, s = apply_layer(p["rem"][str(i)], x, cfg=cfg,
                           kind=cfg.block_pattern[i], mesh=mesh,
                           state=state[f"rem{i}"], pos=pos, enc_out=enc_out,
                           backend=backend)
        new_state[f"rem{i}"] = s
    logits = logits_from_hidden(p, cfg, x, mesh, backend=backend)
    return logits, new_state


# ---------------------------------------------------------------------------
# Paged decode (continuous-batching serving: INT8 pools + page table)
# ---------------------------------------------------------------------------

def init_paged_layer_state(cfg: ModelConfig, kind: str, batch: int,
                           page_size: int, n_pages: int) -> Params:
    """Fresh paged decode state for one layer.

    Attention layers get shared INT8 page pools plus per-(slot, kv-head)
    running PO2 exponents (``repro.serving.paged_cache``); recurrent kinds
    keep their position-free per-slot states.  "local" (ring-buffer)
    layers are not paged yet.
    """
    if kind == "attn":
        from repro.serving.paged_cache import EXP_FLOOR
        shape = (n_pages, page_size, cfg.n_kv_heads, cfg.hd)
        return {"k_pages": jnp.zeros(shape, jnp.int8),
                "v_pages": jnp.zeros(shape, jnp.int8),
                "k_exp": jnp.full((batch, cfg.n_kv_heads), EXP_FLOOR,
                                  jnp.int32),
                "v_exp": jnp.full((batch, cfg.n_kv_heads), EXP_FLOOR,
                                  jnp.int32)}
    if kind == "local":
        raise NotImplementedError(
            "paged serving does not cover local-attention layers yet")
    return init_layer_state(cfg, kind, batch, cache_len=1)


def init_paged_decode_state(cfg: ModelConfig, batch: int, *, page_size: int,
                            n_pages: int) -> Params:
    """Paged analogue of ``init_decode_state`` (same tree structure)."""
    def unit_state():
        return {str(i): init_paged_layer_state(cfg, kind, batch, page_size,
                                               n_pages)
                for i, kind in enumerate(cfg.block_pattern)}

    state: Params = {}
    if cfg.n_units:
        if cfg.scan_layers:
            state["units"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_units,) + a.shape),
                unit_state())
        else:
            state["units"] = {f"u{i}": unit_state()
                              for i in range(cfg.n_units)}
    for i in range(cfg.n_rem):
        state[f"rem{i}"] = init_paged_layer_state(
            cfg, cfg.block_pattern[i], batch, page_size, n_pages)
    return state


def forward_paged_chunk(
    p: Params,
    cfg: ModelConfig,
    state: Params,
    tokens: jax.Array,
    pos: jax.Array,
    page_table: jax.Array,
    *,
    mesh=None,
    backend=None,
):
    """One prefill chunk (or decode step, C=1) over the paged INT8 cache.

    ``tokens`` [B, C] is a block of C consecutive prompt tokens whose
    first token sits at per-slot position ``pos`` [B]; ``page_table``
    [B, n_max] maps each slot's logical pages to physical pool pages.
    All non-attention GEMMs run once at m=C; attention layers write the
    chunk's quantized KV through the same per-token bump-rescale
    recurrence as decode (the paged pools end bit-identical to C
    single-token calls) and attend with an in-chunk causal mask.
    Recurrent layers run exact sequential scans (``apply_layer`` forces
    rwkv impl="scan" / rglru exact_scan when C > 1), so the carried
    states match the token-by-token path bit-for-bit too.

    Returns (logits [B, 1, V] for the LAST chunk row, new_state)."""
    x = jnp.take(p["embed"]["table"], tokens, axis=0)

    new_state = dict(state)
    if cfg.n_units:
        if cfg.scan_layers:
            def body(carry, xs):
                unit_p, unit_s = xs
                y, s = apply_unit(unit_p, carry, cfg=cfg, mesh=mesh,
                                  state=unit_s, pos=pos, backend=backend,
                                  page_table=page_table)
                return y, s

            x, new_units = jax.lax.scan(body, x, (p["units"], state["units"]))
            new_state["units"] = new_units
        else:
            new_units = {}
            for i in range(cfg.n_units):
                x, s = apply_unit(p["units"][f"u{i}"], x, cfg=cfg, mesh=mesh,
                                  state=state["units"][f"u{i}"], pos=pos,
                                  backend=backend, page_table=page_table)
                new_units[f"u{i}"] = s
            new_state["units"] = new_units
    for i in range(cfg.n_rem):
        x, s = apply_layer(p["rem"][str(i)], x, cfg=cfg,
                           kind=cfg.block_pattern[i], mesh=mesh,
                           state=state[f"rem{i}"], pos=pos,
                           backend=backend, page_table=page_table)
        new_state[f"rem{i}"] = s
    logits = logits_from_hidden(p, cfg, x[:, -1:], mesh, backend=backend)
    return logits, new_state


def decode_step_paged(
    p: Params,
    cfg: ModelConfig,
    state: Params,
    token: jax.Array,
    pos: jax.Array,
    page_table: jax.Array,
    *,
    mesh=None,
    backend=None,
):
    """One decode step over the paged INT8 KV cache.

    Unlike ``decode_step``, ``pos`` is a per-slot [B] int32 vector (slots
    advance independently under continuous batching) and ``page_table``
    [B, n_max] maps each slot's logical pages to physical pool pages.
    Returns (logits [B, 1, V], new_state).  This is exactly
    ``forward_paged_chunk`` with a chunk of one token."""
    return forward_paged_chunk(p, cfg, state, token, pos, page_table,
                               mesh=mesh, backend=backend)


# ---------------------------------------------------------------------------
# State-tree slot axes (shared by the serving engines and the fused decode)
# ---------------------------------------------------------------------------

def batch_state_axes(state: Params, scan_layers: bool = True) -> Params:
    """Per-leaf slot axis of a dense decode state: stacked unit states are
    [n_units, B, ...] -> 1; unstacked / remainder states are [B, ...] -> 0."""
    def f(path, a):
        names = [str(getattr(p, "key", "")) for p in path]
        return 1 if (scan_layers and "units" in names) else 0
    return jax.tree_util.tree_map_with_path(f, state)


def paged_state_axes(state: Params, scan_layers: bool = True) -> Params:
    """Per-leaf slot axis of a paged decode state.

    Page pools (``k_pages``/``v_pages``) are shared by every slot and get
    the sentinel -1 (pass whole / take whole); per-slot leaves (running
    exponents, recurrent states) get their slot axis as in
    ``batch_state_axes``."""
    def f(path, a):
        names = [str(getattr(p, "key", "")) for p in path]
        if names and names[-1] in ("k_pages", "v_pages"):
            return -1
        return 1 if (scan_layers and "units" in names) else 0
    return jax.tree_util.tree_map_with_path(f, state)


def _keep_slots(old, new, ax: int, on: jax.Array):
    """Revert a state leaf to ``old`` for slots where ``on`` is False.
    ``ax`` is the leaf's slot axis (-1: shared pool leaf, always new)."""
    if ax == -1:
        return new
    m = on.reshape((1,) * ax + (-1,) + (1,) * (new.ndim - ax - 1))
    return jnp.where(m, new, old)


def decode_horizon_paged(
    p: Params,
    cfg: ModelConfig,
    state: Params,
    tokens: jax.Array,
    pos: jax.Array,
    page_table: jax.Array,
    *,
    horizon: int,
    active: jax.Array,
    budget: jax.Array,
    remaining: jax.Array,
    eos: jax.Array,
    greedy: bool = True,
    temperature: float = 1.0,
    rng: jax.Array | None = None,
    mesh=None,
    backend=None,
):
    """Fused multi-step decode: ``horizon`` iterations of
    ``decode_step_paged`` inside ONE ``lax.scan``, with sampling, per-slot
    EOS / token-budget detection, position advance and paged-KV writes all
    on device — the serving engine syncs with the host once per macro-step
    instead of once per token.

    ``tokens`` [B, 1] are each slot's last generated tokens (0 for masked
    slots); ``pos`` [B] the positions they will be written at;
    ``page_table`` [B, n_max] the PRE-BUILT physical page map covering
    every position the scan can reach (the engine's ``_ensure_capacity``
    reserves [pos, pos + budget) up front).  Per-slot int32/bool vectors:

      * ``active``    — False: empty or mid-prefill slot; rides the batch
        inert for the whole horizon (null-page writes, leaves reverted).
      * ``budget``    — device steps the slot may take this macro-step
        (<= horizon; the engine shrinks it when the page pool is tight).
      * ``remaining`` — tokens left before ``max_new_tokens``.
      * ``eos``       — per-slot stop token id, -1 when none.

    Step ``t`` masks a slot exactly the way the engine's single-step path
    masks non-decoding slots — zeroed table row (writes land on the null
    page), per-slot leaves reverted, fed token 0, position frozen — so
    ``horizon`` fused steps are token- AND KV-bit-identical to ``horizon``
    single ``decode_step_paged`` calls with host-side masking, including a
    slot that hits EOS or exhausts its token budget mid-horizon.
    Recurrent (rwkv/rglru) per-step states ride the scan carry like every
    other per-slot leaf.

    Returns ``(tok_block [B, horizon], emitted [B, horizon] bool,
    new_state, new_pos, new_rng)``; ``emitted[s]`` is a prefix mask — the
    host appends ``tok_block[s, t]`` for every True ``emitted[s, t]``."""
    from repro.serving.paged_cache import NULL_PAGE
    axes = paged_state_axes(state, cfg.scan_layers)
    if rng is None:
        rng = jax.random.PRNGKey(0)
    temp = jnp.maximum(temperature, 1e-6)

    def body(carry, _):
        st, tok, ps, act, bud, rem, key = carry
        on = act & (bud > 0)
        tbl = jnp.where(on[:, None], page_table, NULL_PAGE)
        lg, st2 = decode_step_paged(p, cfg, st, tok, ps, tbl,
                                    mesh=mesh, backend=backend)
        st2 = jax.tree.map(lambda o, n, ax: _keep_slots(o, n, ax, on),
                           st, st2, axes)
        logits = lg[:, -1] / temp
        key, sub = jax.random.split(key)
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(sub, logits,
                                         axis=-1).astype(jnp.int32)
        rem2 = jnp.where(on, rem - 1, rem)
        fin = on & ((nxt == eos) | (rem2 <= 0))
        tok2 = jnp.where(on, jnp.where(fin, 0, nxt), tok[:, 0])[:, None]
        carry2 = (st2, tok2, ps + on.astype(ps.dtype), act & ~fin,
                  bud - on.astype(bud.dtype), rem2, key)
        return carry2, (nxt, on)

    carry = (state, tokens, jnp.asarray(pos, jnp.int32), active,
             jnp.asarray(budget, jnp.int32), jnp.asarray(remaining,
                                                         jnp.int32), rng)
    (st, _, ps, _, _, _, key), (toks, ons) = jax.lax.scan(
        body, carry, None, length=horizon)
    return (jnp.moveaxis(toks, 0, 1), jnp.moveaxis(ons, 0, 1), st, ps, key)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: jax.Array | None = None, z_loss: float = 0.0):
    """Token-mean cross entropy in fp32 (+ optional z-loss), vocab-shard safe.

    logits: [B, S, V]; labels: [B, S] int32; mask: [B, S] (1 = contributes).
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
