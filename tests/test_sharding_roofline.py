"""Sharding rules, gradient compression math, HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import (
    dequantize_grad,
    optimizer_spec,
    quantize_grad,
    spec_for,
    tree_specs,
)
from repro.roofline import analyze_hlo, cost_terms, model_flops
from repro.launch.mesh import make_smoke_mesh


class FakeMesh:
    """Minimal stand-in with axis_names/shape (no devices needed)."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


# ------------------------------ sharding rules ----------------------------

def test_spec_for_basic_tp():
    s = spec_for(("embed", "ff"), (4096, 11008), MESH)
    assert s == P("data", "model")


def test_spec_for_divisibility_fallback():
    # 40 heads % 16 != 0 -> replicate that axis
    s = spec_for(("batch", None, "heads", None), (32, 1, 40, 64), MESH)
    assert s[2] is None
    assert s[0] == ("pod", "data")


def test_spec_for_batch_fallback_to_data():
    # batch 16 not divisible by pod*data=32 -> falls back to data(16)
    s = spec_for(("batch", None), (16, 128), MESH)
    assert s[0] == "data"
    # batch 3 -> fully replicated
    s = spec_for(("batch", None), (3, 128), MESH)
    assert s == P(None, None)


def test_spec_for_no_axis_reuse():
    # both dims want "model": second falls back to replication
    s = spec_for(("ff", "vocab"), (1536, 151936), MESH)
    assert s == P("model", None)


def test_optimizer_spec_zero1():
    s = optimizer_spec(P("data", "model"), (4096, 8192), MESH)
    assert s == P("data", "model")  # nothing replicated -> unchanged
    s = optimizer_spec(P(None, "model"), (4096, 8192), MESH)
    assert s == P("pod", "model")  # first replicated divisible dim -> pod


def test_tree_specs_structure():
    spec_tree = {"a": ("embed", "ff"), "b": {"c": ("norm",)}}
    shape_tree = {"a": jax.ShapeDtypeStruct((64, 32), jnp.float32),
                  "b": {"c": jax.ShapeDtypeStruct((7,), jnp.float32)}}
    out = tree_specs(spec_tree, shape_tree, MESH)
    assert out["a"] == P("data", "model")
    assert out["b"]["c"] == P(None)


# ------------------------------ compression -------------------------------

def test_grad_quantize_roundtrip_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (1024,))
    codes, scale = quantize_grad(g)
    back = dequantize_grad(codes, scale)
    assert codes.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-7


def test_grad_compression_error_feedback_converges():
    """With error feedback, the accumulated quantized sum tracks the true
    sum (residual stays bounded)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (256,)) * 1e-3
    residual = jnp.zeros_like(g)
    acc_q = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for i in range(20):
        gi = g * (1 + 0.1 * i)
        x = gi + residual
        codes, scale = quantize_grad(x)
        back = dequantize_grad(codes, scale)
        residual = x - back
        acc_q = acc_q + back
        acc = acc + gi
    rel = float(jnp.linalg.norm(acc_q - acc) / jnp.linalg.norm(acc))
    assert rel < 0.05, rel


# ------------------------------ roofline ----------------------------------

def test_analyze_hlo_matches_cost_analysis_unrolled():
    def f(x, w):
        for _ in range(3):
            x = jnp.tanh(x @ w)
        return x
    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(xs, ws).compile()
    r = analyze_hlo(c.as_text())
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert r["flops"] == pytest.approx(float(ca["flops"]), rel=0.01)


def test_analyze_hlo_scan_trip_multiplication():
    def body(c, w):
        return c @ w, ()
    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y
    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    c = jax.jit(f).lower(xs, ws).compile()
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 32 * 64 * 64 * 7, rel=1e-6)


def test_analyze_hlo_grad_shows_remat_waste():
    def body(c, w):
        return jax.checkpoint(lambda a, b: jnp.tanh(a @ b))(c, w), ()
    def loss(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)
    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = jax.jit(jax.grad(loss)).lower(xs, ws).compile()
    r = analyze_hlo(c.as_text())
    fwd = 2 * 32 * 64 * 64 * 5
    assert r["flops"] == pytest.approx(3 * fwd, rel=0.05)  # recompute + 2 bwd


def test_cost_terms_dominant():
    t = cost_terms({"flops": 197e12, "bytes accessed": 819e9 * 2},
                   {"total": 0}, n_chips=1)
    assert t["dominant"] == "memory_s"
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["roofline_fraction"] == pytest.approx(0.5)


def test_model_flops():
    assert model_flops(1_000_000, 100, training=True) == 6e8
    assert model_flops(1_000_000, 100, training=False) == 2e8


def test_smoke_mesh_constraint_roundtrip():
    """with_sharding_constraint under the 1-device production-named mesh."""
    mesh = make_smoke_mesh()
    from jax.sharding import NamedSharding
    f = jax.jit(lambda x: jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P("data", None))) * 2)
    y = f(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(y), 2 * np.ones((4, 4)))
