"""internvl2-26b — InternVL2 26B [arXiv:2404.16821; hf].

LM BACKBONE (InternLM2-20B) only: 48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92553.  The InternViT frontend is a STUB:
``input_specs`` provides precomputed patch embeddings [B, 256, d_model]
which are projected and prepended to the text tokens.
"""
from repro.models.config import ModelConfig

N_IMAGE_TOKENS = 256  # 448px / 14 patch / 2x2 pixel-shuffle = 16x16

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    norm="rmsnorm",
    mlp="swiglu",
    frontend="vision",
    n_frontend_tokens=N_IMAGE_TOKENS,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, frontend="vision",
        n_frontend_tokens=4, dtype="float32")
