"""Low-bit gradient compression for the DCN ("pod") axis.

Cross-pod gradient reduction is the one collective that crosses the slow
data-center network; quantizing each leaf to INT8 (or packed INT4) with a
per-leaf scale cuts those bytes 4x (8x).  The trainer composes this inside
``shard_map`` over "pod" only — ICI-axis reductions stay in autodiff at
full precision.  Error feedback (caller-held residual) keeps the
accumulated quantized sum tracking the true sum; see
``tests/test_sharding_roofline.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SUPPORTED_BITS = (4, 8)


def quantize_grad(g: jax.Array, bits: int = 8):
    """Per-tensor symmetric low-bit codes + float scale for one gradient.

    Codes are held in an int8 carrier regardless of ``bits`` (the 4-bit
    wire format packs two codes per byte, see ``pack_int4``).
    """
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / qmax + 1e-30
    codes = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                     -qmax - 1, qmax).astype(jnp.int8)
    return codes, scale


def dequantize_grad(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def pack_int4(codes: jax.Array) -> jax.Array:
    """Two 4-bit codes (int8 carrier, values in [-8, 7]) per wire byte."""
    flat = codes.reshape(-1)
    if flat.size % 2:
        flat = jnp.pad(flat, (0, 1))
    hi, lo = flat[0::2], flat[1::2]
    return (jnp.left_shift(hi, 4) | (lo & 0xF)).astype(jnp.int8)


def unpack_int4(packed: jax.Array, size: int, shape: tuple) -> jax.Array:
    """Inverse of ``pack_int4`` (arithmetic shifts sign-extend exactly)."""
    hi = jnp.right_shift(packed, 4)
    lo = ((packed & 0xF) ^ 8) - 8
    flat = jnp.stack([hi, lo], axis=-1).reshape(-1)[:size]
    return flat.reshape(shape).astype(jnp.int8)


def wire_bytes(n_elements: int, bits: int) -> int:
    """Actual on-wire payload of one leaf's codes (excl. the fp32 scale)."""
    return -(-n_elements * bits // 8)


def compress_tree_psum(tree, axis_name: str, bits: int = 8):
    """Quantize every leaf to ``bits`` codes, then average across
    ``axis_name``.

    The collective moves the *packed codes* (all_gather + local
    dequantize-mean), not dequantized fp32 — each pod holds its own
    per-leaf scale, so a direct fp32 psum would forfeit the byte saving
    this module exists for.  ``bits`` must be one of ``SUPPORTED_BITS``
    (4-bit packs code pairs into wire bytes; anything else raises —
    silently widening to 8 would misreport the DCN budget).  Returns
    ``(tree, info)`` where ``info["wire_bytes"]`` is the actual per-pod
    payload this call put on the wire (codes at ``bits`` plus one fp32
    scale per leaf) next to the fp32 baseline; ``info["int8_bytes"]``
    keeps the legacy 8-bit-path accounting.  Must run inside
    ``shard_map`` (or any context where ``axis_name`` is bound).
    """
    if bits not in SUPPORTED_BITS:
        raise ValueError(
            f"compress_tree_psum supports bits in {SUPPORTED_BITS}, "
            f"got {bits} — refusing to silently widen the wire format")

    def f(g):
        codes, scale = quantize_grad(g, bits)
        if bits == 4:
            packed = pack_int4(codes)                       # 2 codes/byte
            all_packed = jax.lax.all_gather(packed, axis_name)
            all_codes = jax.vmap(
                lambda p: unpack_int4(p, codes.size, codes.shape)
            )(all_packed)
        else:
            all_codes = jax.lax.all_gather(codes, axis_name)  # int8 on wire
        all_scales = jax.lax.all_gather(scale, axis_name)     # one fp32/pod
        deq = all_codes.astype(jnp.float32) * all_scales.reshape(
            (-1,) + (1,) * codes.ndim)
        return jnp.mean(deq, axis=0)

    out = jax.tree.map(f, tree)
    leaves = jax.tree.leaves(tree)
    n = sum(int(x.size) for x in leaves)
    wire = sum(wire_bytes(int(x.size), bits) + 4 for x in leaves)
    info = {"bits": bits, "wire_bytes": wire,
            "int8_bytes": n, "fp32_bytes": 4 * n}
    return out, info
