"""The paper's PSUM-precision-aware analytical accelerator model (§II-A).

Implements eqs (1)-(6) exactly: per-dataflow (IS / WS / OS) SRAM and DRAM
access counts for ifmap / weight / PSUM / ofmap as a function of layer
geometry, MAC-array parallelism (P_o, P_ci, P_co), buffer capacities
(B_i, B_w, B_o) and the PSUM precision factor beta = psum_bits / 8.

Energy constants follow Horowitz ISSCC'14 [21] as the paper does:
INT8 MAC 0.23 pJ; on-chip SRAM ~2.5 pJ/byte (32-256 KB class); off-chip
DDR3 ~160 pJ/byte.  Absolute joules depend on these constants; every paper
figure is *normalized*, which this module reproduces.

Grouping (Algorithm 1) interacts with the model in exactly one place: the
PSUM buffer-capacity conditions scale by ``gs`` (gs INT8 PSUM tiles are
live at once), while total access counts are unchanged — the paper states
this explicitly (§III-B) and Fig. 6's energy cliffs for Segformer /
EfficientViT at gs >= 3 fall out of it.
"""
from __future__ import annotations

import dataclasses
import math


# ---------------------------------------------------------------------------
# Constants (Horowitz [21])
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EnergyConstants:
    e_mac_int8: float = 0.23e-12     # pJ: 0.2 (8b mult) + 0.03 (add)
    e_sram_byte: float = 2.5e-12     # ~10 pJ / 32-bit word, 128 KB class
    e_dram_byte: float = 160e-12     # ~640 pJ / 32-bit word, DDR3


HORO = EnergyConstants()


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """The analytical DNN accelerator of Fig. 2 (paper §IV-A defaults)."""
    P_o: int = 16          # ofmap parallelism (tokens/pixels per tile)
    P_ci: int = 8          # input-channel parallelism
    P_co: int = 8          # output-channel parallelism
    B_i: int = 256 * 1024  # ifmap buffer bytes
    B_w: int = 128 * 1024  # weight buffer bytes
    B_o: int = 256 * 1024  # ofmap/PSUM buffer bytes

    @staticmethod
    def llm_decode() -> "AcceleratorConfig":
        """LLM setting (§IV-D): P_o=1 (vector ifmap), P_ci=P_co=32."""
        return AcceleratorConfig(P_o=1, P_ci=32, P_co=32)


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One GEMM layer: [tokens, C_i] @ [C_i, C_o] (1x1-conv view).

    ``tokens`` is H_o * W_o for CV models and the token count for NLP.
    """
    name: str
    tokens: int
    c_i: int
    c_o: int
    repeat: int = 1        # e.g. per-head attention GEMMs


DATAFLOWS = ("IS", "WS", "OS")


@dataclasses.dataclass(frozen=True)
class LayerEnergySpec:
    """One layer's *resolved* energy knobs (heterogeneous per-layer model).

    The paper's Table IV uses one global beta; the RAE's reconfigurability
    (§III-C) makes ``(gs, psum_bits)`` — and even the dataflow — per-layer
    choices.  ``repro.search`` resolves a ``QuantPolicy`` against a model's
    GEMM inventory into a list of these; ``model_energy`` consumes them
    directly (a plain ``LayerShape`` is shorthand for the uniform knobs
    passed as keyword arguments).

    ``n_p`` overrides the accelerator-derived tile count
    ``ceil(C_i / P_ci)`` — a policy's K-tiling choice maps onto the
    hardware as a different effective input-channel parallelism, scaling
    the PSUM read-modify-write traffic (eqs 3-6 count ``2(n_p - 1)``
    buffer accesses per output).
    """

    layer: LayerShape
    psum_bits: int = 32
    gs: int = 1
    dataflow: str | None = None   # None -> the model-level dataflow
    n_p: int | None = None        # None -> ceil(C_i / P_ci)


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def access_counts(layer: LayerShape, acc: AcceleratorConfig, dataflow: str,
                  *, beta: float, gs: int = 1,
                  n_p: int | None = None) -> dict:
    """Eqs (3)-(6): access *multipliers* N^{i,w,p,o} for SRAM and DRAM.

    beta: PSUM precision ratio (psum_bits / 8); enters the capacity
    conditions via the live tile size S~_p = beta * P_o * P_co and eq (2)
    via the beta * S_o * N^p term (handled in ``layer_energy``).
    gs: number of live PSUM tiles (Algorithm 1 grouping) — scales only the
    capacity conditions.
    n_p: PSUM tile count along K; defaults to the accelerator-derived
    ``ceil(C_i / P_ci)`` (a per-layer policy override models a different
    effective P_ci for this layer).
    """
    T, Ci, Co = layer.tokens, layer.c_i, layer.c_o
    S_i, S_w, S_o = T * Ci, Ci * Co, T * Co  # bytes at INT8
    if n_p is None:
        n_p = _ceil(Ci, acc.P_ci)
    n_p = max(1, min(n_p, Ci))

    if dataflow == "IS":
        # ifmap tile = P_o tokens held in the array; weights stream.
        n_tiles = _ceil(T, acc.P_o)
        if S_w < acc.B_w:
            ns_w, nd_w = 1 + n_tiles, 1
        else:
            ns_w, nd_w = 2 * n_tiles, n_tiles
        ns_i, nd_i = 2, 1
        # Live PSUM: all Co/P_co output-channel tiles of the current ifmap
        # tile: (Co/P_co) * S~_p, S~_p = beta * gs * P_i * P_co.
        live = _ceil(Co, acc.P_co) * beta * gs * acc.P_o * acc.P_co
        if live <= acc.B_o:
            ns_p, nd_p = 2 * (n_p - 1), 0
        else:
            ns_p, nd_p = 4 * (n_p - 1), 2 * (n_p - 1)
        ns_o, nd_o = 2, 1
    elif dataflow == "WS":
        # P_ci x P_co weights held; ifmap tiles stream per Co tile.  The
        # capacity condition uses the *enlarged ifmap tile* S~_i = P_o * C_i
        # (paper: "the input tile size S~i is enlarged based on output
        # tiles, kernels, and strides"), not the full ifmap.
        n_co = _ceil(Co, acc.P_co)
        tile_i = acc.P_o * Ci
        if tile_i < acc.B_i:
            ns_i, nd_i = 1 + n_co, 1
        else:
            ns_i, nd_i = 2 * n_co, n_co
        ns_w, nd_w = 2, 1
        # Live PSUM: every ofmap-row tile in flight: (T/P_o) * S~_p.
        live = _ceil(T, acc.P_o) * beta * gs * acc.P_o * acc.P_co
        if live <= acc.B_o:
            ns_p, nd_p = 2 * (n_p - 1), 0
        else:
            ns_p, nd_p = 4 * (n_p - 1), 2 * (n_p - 1)
        ns_o, nd_o = 2, 1
    elif dataflow == "OS":
        # PSUMs pinned in PE registers: no PSUM buffer traffic at all, but
        # ifmap and weight stream repeatedly (classic OS trade-off).
        ns_i, nd_i = 1 + _ceil(Co, acc.P_co), 1
        ns_w, nd_w = 1 + _ceil(T, acc.P_o), 1
        ns_p = nd_p = 0
        ns_o, nd_o = 2, 1
    else:
        raise ValueError(dataflow)

    return {
        "sram": {"i": ns_i, "w": ns_w, "p": ns_p, "o": ns_o},
        "dram": {"i": nd_i, "w": nd_w, "p": nd_p, "o": nd_o},
        "sizes": {"i": S_i, "w": S_w, "o": S_o},
        "n_p": n_p,
    }


def layer_energy(layer: LayerShape, acc: AcceleratorConfig, dataflow: str,
                 *, psum_bits: int = 32, gs: int = 1, n_p: int | None = None,
                 consts: EnergyConstants = HORO) -> dict:
    """Eq (1)+(2): energy breakdown {ifmap, weight, psum, ofmap, op} in J."""
    beta = psum_bits / 8.0
    cnt = access_counts(layer, acc, dataflow, beta=beta, gs=gs, n_p=n_p)
    S = cnt["sizes"]
    r = layer.repeat

    def traffic(level: str) -> dict:
        n = cnt[level]
        return {
            "ifmap": S["i"] * n["i"],
            "weight": S["w"] * n["w"],
            "psum": beta * S["o"] * n["p"],
            "ofmap": S["o"] * n["o"],
        }

    sram_b = traffic("sram")
    dram_b = traffic("dram")
    macs = layer.tokens * layer.c_i * layer.c_o
    out = {}
    for k in ("ifmap", "weight", "psum", "ofmap"):
        out[k] = r * (sram_b[k] * consts.e_sram_byte
                      + dram_b[k] * consts.e_dram_byte)
    out["op"] = r * macs * consts.e_mac_int8
    out["total"] = sum(out.values())
    out["sram_bytes"] = r * sum(sram_b.values())
    out["dram_bytes"] = r * sum(dram_b.values())
    out["macs"] = r * macs
    return out


def model_energy(layers: list, acc: AcceleratorConfig, dataflow: str,
                 *, psum_bits: int = 32, gs: int = 1,
                 consts: EnergyConstants = HORO) -> dict:
    """Sum of ``layer_energy`` over a model's layer walk.

    ``layers`` mixes plain ``LayerShape`` entries (which take the uniform
    ``psum_bits``/``gs``/``dataflow`` given here — the paper's global-beta
    setting) and ``LayerEnergySpec`` entries carrying their own per-layer
    knobs (the heterogeneous model ``repro.search`` scores policies with).
    """
    total = {k: 0.0 for k in ("ifmap", "weight", "psum", "ofmap", "op",
                              "total", "sram_bytes", "dram_bytes", "macs")}
    for layer in layers:
        if isinstance(layer, LayerEnergySpec):
            e = layer_energy(layer.layer, acc, layer.dataflow or dataflow,
                             psum_bits=layer.psum_bits, gs=layer.gs,
                             n_p=layer.n_p, consts=consts)
        else:
            e = layer_energy(layer, acc, dataflow, psum_bits=psum_bits,
                             gs=gs, consts=consts)
        for k in total:
            total[k] += e[k]
    return total


def energy_summary(layers: list, acc: AcceleratorConfig,
                   *, dataflows=("IS", "WS"), psum_bits_list=(32, 8),
                   gs_list=(1, 2, 3, 4)) -> dict:
    """Grid of normalized energies: the engine behind Figs 1/5/6, Table IV.

    Returns {dataflow: {"baseline": E(int32), ("gs", g): E(int8, g)}}.
    """
    out: dict = {}
    for df in dataflows:
        row = {"baseline": model_energy(layers, acc, df, psum_bits=32)}
        for g in gs_list:
            row[("gs", g)] = model_energy(layers, acc, df, psum_bits=8, gs=g)
        out[df] = row
    return out


def savings(baseline: dict, apsq: dict) -> float:
    """Fractional energy saving (paper's 'energy costs saved by 28-87%')."""
    return 1.0 - apsq["total"] / baseline["total"]
