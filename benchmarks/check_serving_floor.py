"""Gate a fresh serving_bench run against the checked-in serving floor.

CI's serve job runs ``serving_bench --smoke --json`` and then this script
with the floor extracted from the committed ``BENCH_serving.json``
(``git show HEAD:BENCH_serving.json``), mirroring
``check_kernel_floor.py`` for the kernel-backend job.  Load records are
matched on (streams, max_batch); each match must hold

  * ``tokens_per_s``  at or above ``floor * slack``          (throughput)
  * ``ttft_p50_ms``   at or below ``floor / slack``          (latency)

and the fresh run's parity record must be all-green (a throughput number
from an engine that diverged from the single-stream oracle is
worthless).  Wall-clock on a shared CI box is noisy, so the default
slack is generous — the gate exists to catch scheduler/prefill
regressions that cost multiples (e.g. re-serializing the chunked
prefill), not 10% jitter.

Exit codes: 0 pass, 1 regression, 2 usage/IO error.  No overlapping
load records is a warning, not a failure (a floor from before a load
cell existed cannot gate it).
"""
import argparse
import json
import sys


def _load_records(payload: dict) -> dict:
    out = {}
    for rec in payload.get("records", []):
        if rec.get("section") != "load":
            continue
        out[(rec.get("streams"), rec.get("max_batch"))] = rec
    return out


def _parity_ok(payload: dict) -> bool:
    for rec in payload.get("records", []):
        if rec.get("section") == "parity":
            return bool(rec.get("batched_eq_single")
                        and rec.get("pallas_eq_oracle"))
    return False


def check(new: dict, floor: dict, slack: float, print_fn=print) -> int:
    if not _parity_ok(new):
        print_fn("floor,FAIL,parity record missing or not green — "
                 "refusing to gate throughput of a diverged engine")
        return 1
    new_recs = _load_records(new)
    floor_recs = _load_records(floor)
    overlap = sorted(set(new_recs) & set(floor_recs))
    if not overlap:
        print_fn("floor,WARN,no overlapping load records — nothing to "
                 "gate (floor predates these load cells?)")
        return 0
    failures = 0
    for key in overlap:
        streams, max_batch = key
        rec, ref = new_recs[key], floor_recs[key]
        tps, tps_need = rec.get("tokens_per_s", 0.0), \
            ref.get("tokens_per_s", 0.0) * slack
        ttft = rec.get("ttft_p50_ms", float("inf"))
        ttft_need = ref.get("ttft_p50_ms", 0.0) / slack
        ok = tps >= tps_need and ttft <= ttft_need
        print_fn(f"floor,{'ok' if ok else 'FAIL'},streams={streams},"
                 f"max_batch={max_batch},"
                 f"tokens_per_s={tps} (floor*slack={tps_need:.1f}),"
                 f"ttft_p50_ms={ttft} (floor/slack={ttft_need:.1f})")
        failures += 0 if ok else 1
    if failures:
        print_fn(f"floor,FAIL,{failures}/{len(overlap)} load cells "
                 f"regressed past the checked-in serving floor")
        return 1
    print_fn(f"floor,pass,{len(overlap)} load cells within the serving "
             f"floor")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new_json", help="fresh serving_bench --json output")
    ap.add_argument("floor_json",
                    help="committed BENCH_serving.json to gate against")
    ap.add_argument("--slack", type=float, default=0.25,
                    help="required fraction of the floor (default 0.25: "
                         "flag >4x regressions, tolerate shared-box "
                         "timing noise)")
    args = ap.parse_args(argv)
    try:
        with open(args.new_json) as f:
            new = json.load(f)
        with open(args.floor_json) as f:
            floor = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"floor,ERROR,{e}")
        return 2
    return check(new, floor, args.slack)


if __name__ == "__main__":
    raise SystemExit(main())
