"""Tensor/expert-parallel placement + collectives for deployed integer models.

This module is the serving-side mesh story (ROADMAP item 3): it decides,
per exported ``DeployedQuantState``, how the INT8 code banks are split over
the ``model`` mesh axis, and provides the shard_map bodies the ``sharded``
exec backend (``repro.exec.ShardedBackend``) runs — with INT8-on-the-wire
combines wherever the PO2-grid invariant makes them lossless.

Shard rules (``plan_gemm``) — derived from Algorithm-1 semantics, not
preference, and shared verbatim by placement and execution so both always
agree:

  * **PSQ** (``gs >= n_p``): every PSUM tile except the final one is
    quantized *independently* (carry-free), so the K axis shards into
    whole-PSUM-tile spans — each device owns ``n_p/D`` contiguous tiles of
    codes, quantizes/dequantizes them locally on the PO2 grid, and the
    INT32 partials combine exactly.  A ragged ``K % n_p`` remainder group
    (zero-padded, contributes nothing) always falls inside the LAST
    device's span because tile order is preserved.  On the int8 wire path
    the combine is ``psum_scatter`` (int32) + final-quantize per N-slice +
    int8 code ``all_gather`` — 5 bytes/elt vs 8 for the full-precision
    psum.  Requires ``n_p % D == 0``.
  * **APSQ** (``gs < n_p``): the group-start code chain
    ``stored[i] = Q(tiles[i] + sum deq(stored[i-gs..i]))`` is *sequential
    along K* — a K-shard cannot reproduce it without a device-serial carry
    pipeline, and quantizing INT32 partials for the wire would break
    bit-exactness.  So APSQ layers shard **N** (column-parallel): each
    device runs the full recurrence on its column slice, and because the
    layer's final output is by construction an INT8 code times the static
    ``2^e_last``, the combine is a *lossless* INT8 ``all_gather`` of codes
    (arithmetic right-shift by ``e_last``, gather, left-shift) — exactly
    4x fewer wire bytes than an fp32/int32 gather.  Requires
    ``N % D == 0``.
  * **W8A8** (``psum_exps is None``): plain INT32 accumulation — K-shards
    by even column spans with an exact int32 ``psum`` (full precision on
    the wire on both paths; quantizing the partials would be lossy and is
    refused).
  * **MoE expert banks**: the stacked expert axis shards over ``model``
    (EP).  Dispatch needs no collective — activations are replicated over
    ``model`` and each device slices its experts' rows — and the combine
    gathers per-expert *outputs as INT8 codes* (each expert's ``e_last``
    is static), so the all-to-all-equivalent moves 1 byte/elt.
  * Anything that misses its divisibility constraint falls back
    (psq -> "n" -> replicate) rather than erroring; ``LayerPlan.axis ==
    "replicate"`` layers run the single-device path unchanged.

Exponent banks: the big data — ``[K, N]`` code banks — shard; the
``[n_p]``/``[n_p, N]`` PSUM exponent banks stay REPLICATED everywhere
(they are noise next to the codes: ``n_p x N`` int32 vs ``K x N`` int8).
Column-parallel and expert-parallel bodies slice their local span from
the replicated bank at trace time, so the full ``e_last`` needed to
finish the INT8 code gather is already resident — no per-call exponent
sidecar ever crosses the wire (at decode ``m = 1`` a ``4 x N`` sidecar
would cost more than the code gather it annotates).

``shard_deployed`` walks an exported tree, ``device_put``s every leaf with
its ``NamedSharding``, and returns a ``{name: LayerPlan}`` report whose
``wire_bytes(m)`` is computed *analytically* from static shapes — this is
what ``benchmarks/dist_bench.py`` aggregates, so the int8-vs-fp32 wire
accounting can't drift from the placement that actually ran.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import DeployedQuantState, QuantConfig
from repro.kernels.apsq_matmul.ref import dequantize_psum, quantize_psum

from .sharding import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """``repro.dist.shard_map`` adapted for the serving collectives.

    Differences from the raw wrapper, both forced by how these bodies
    are used:

      * manual over EVERY mesh axis, not just ``model`` — the bodies use
        ``axis_index``, which lowers to a ``PartitionId`` op that GSPMD
        refuses to partition when other axes (a multi-pod mesh's "pod"/
        "data") stay auto.  Serving replicates all tensors over those
        axes, so full-manual is semantically identical: unmentioned axes
        in the specs mean replicated slices.
      * wrapped in ``jit`` — partial- and full-manual shard_map is only
        implemented under a trace; the engines always jit these, but the
        backend ops are public API and must also work eagerly.  Under an
        outer jit the inner one is inlined, no double dispatch.

    ``axis_names`` is accepted (call sites name the collective axis) but
    widened to the full mesh.
    """
    del axis_names
    return jax.jit(_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs,
                              axis_names=set(mesh.axis_names)))

# ---------------------------------------------------------------------------
# The shared placement/execution decision
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """How one [M, K] x [K, N] deployed GEMM splits over D shards."""

    axis: str   # "k" | "n" | "expert" | "replicate"
    mode: str   # "w8a8" | "psq" | "apsq"
    d: int

    @property
    def sharded(self) -> bool:
        return self.axis != "replicate" and self.d > 1


def gemm_mode(n_p: int | None, gs: int) -> str:
    """Mode from the exponent-bank geometry (what the kernel actually runs,
    regardless of what the spec *declares* — gs >= n_p executes as PSQ)."""
    if n_p is None:
        return "w8a8"
    return "psq" if gs >= n_p else "apsq"


def plan_gemm(*, k: int, n: int, n_p: int | None, gs: int,
              d: int) -> GemmPlan:
    """Pick the shard axis for one GEMM.  Pure + static: the ShardedBackend
    re-derives this at trace time from the same shapes ``shard_deployed``
    placed with, so placement and execution cannot disagree."""
    mode = gemm_mode(n_p, gs)
    if d <= 1:
        return GemmPlan("replicate", mode, d)
    if mode == "psq" and n_p % d == 0 and n_p >= d:
        return GemmPlan("k", mode, d)
    if mode == "w8a8" and k % d == 0:
        return GemmPlan("k", mode, d)
    if n % d == 0:
        return GemmPlan("n", mode, d)
    return GemmPlan("replicate", mode, d)


def _dq_geometry(dq: DeployedQuantState, kind: str):
    """(k, n, n_p, gs, lead, units, experts) per-unit geometry of one bank.

    ``lead`` = leading axes before the per-unit [K, N]: scan stacking adds
    one, the expert axis adds one.  Stacking is detected from ``ax_exp``'s
    rank (scalar per plain linear, [E] per expert bank).
    """
    base = 1 if kind == "expert" else 0
    stacked = dq.ax_exp.ndim > base
    lead = base + (1 if stacked else 0)
    k, n = int(dq.w_codes.shape[-2]), int(dq.w_codes.shape[-1])
    units = int(dq.w_codes.shape[0]) if stacked else 1
    experts = int(dq.w_codes.shape[lead - 1]) if kind == "expert" else 1
    n_p = None
    gs = 1
    if dq.psum_exps is not None:
        n_p = int(dq.psum_exps.shape[lead])
        spec = dq.spec or QuantConfig.w8a8()
        gs = n_p if spec.psum.mode == "psq" else spec.psum.gs
    return k, n, n_p, gs, lead, units, experts


# ---------------------------------------------------------------------------
# Wire accounting (analytic, from the static plan)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerPlan:
    """One placed layer: shard decision + analytic wire-byte model.

    Byte convention (both paths, so the ratio is meaningful):
    ``all_gather`` of a logical payload moves payload x itemsize;
    ``psum`` moves 2 x payload x 4 (reduce-scatter + all-gather halves);
    ``psum_scatter`` alone moves payload x 4.  Exponent banks are
    replicated at placement time, so no sidecar term appears.
    """

    name: str
    kind: str        # "linear" | "head" | "expert" | "attn"
    mode: str        # "w8a8" | "psq" | "apsq" | "-"
    axis: str        # "k" | "n" | "expert" | "heads" | "replicate"
    d: int
    k: int = 0
    n: int = 0
    n_p: int | None = None
    gs: int = 1
    units: int = 1
    experts: int = 1
    per_col: bool = False

    def wire_bytes(self, m: int) -> dict:
        """{"int8": bytes, "fp32": bytes} for one call with m rows
        (per expert, for expert banks) under each wire mode."""
        if self.axis == "replicate" or self.d <= 1:
            return {"int8": 0, "fp32": 0}
        payload = self.units * self.experts * m * self.n
        if self.kind == "attn":
            b = payload * 4          # fp32 head gather, identical both paths
            return {"int8": b, "fp32": b}
        if self.mode == "w8a8":
            b = 8 * payload if self.axis == "k" else 4 * payload
            return {"int8": b, "fp32": b}
        if self.axis == "k":         # PSQ: int32 scatter + int8 code gather
            return {"int8": 5 * payload, "fp32": 8 * payload}
        # column-parallel / expert-parallel PSUM-mode: lossless code gather
        return {"int8": payload, "fp32": 4 * payload}


def wire_report(plans: dict, m: int = 1) -> dict:
    """Aggregate ``LayerPlan.wire_bytes`` over a plan dict.

    ``switchable`` sums only the collectives the wire flag actually
    changes (PSUM-mode combines); ``total`` includes the flag-invariant
    ones (w8a8 psums, attention head gathers) so nothing is hidden.
    """
    layers, tot8, tot32, sw8, sw32 = {}, 0, 0, 0, 0
    for name, pl in plans.items():
        b = pl.wire_bytes(m)
        layers[name] = {"axis": pl.axis, "mode": pl.mode, **b}
        tot8 += b["int8"]
        tot32 += b["fp32"]
        if b["int8"] != b["fp32"]:
            sw8 += b["int8"]
            sw32 += b["fp32"]
    return {
        "m": m,
        "layers": layers,
        "total": {"int8": tot8, "fp32": tot32,
                  "ratio": (tot32 / tot8) if tot8 else None},
        "switchable": {"int8": sw8, "fp32": sw32,
                       "ratio": (sw32 / sw8) if sw8 else None},
    }


# ---------------------------------------------------------------------------
# Placement: shard_deployed / shard_paged_state
# ---------------------------------------------------------------------------


def _mesh_dim(mesh, model_axis: str) -> int:
    return int(mesh.shape[model_axis]) if model_axis in mesh.axis_names else 1


def _put(leaf, mesh, spec: P):
    return jax.device_put(leaf, NamedSharding(mesh, spec))


def _place_dq(dq: DeployedQuantState, kind: str, mesh, ax: str,
              plans: dict) -> DeployedQuantState:
    d = _mesh_dim(mesh, ax)
    k, n, n_p, gs, lead, units, experts = _dq_geometry(dq, kind)
    per_col = dq.psum_exps is not None and dq.psum_exps.ndim - lead == 2
    pad = (None,) * lead

    if kind == "expert":
        plan_axis = "expert" if (d > 1 and experts % d == 0) else "replicate"
        mode = gemm_mode(n_p, gs)
        e_ax = (None,) * (lead - 1) + (ax,)
        if plan_axis == "expert":
            w_spec = P(*e_ax, None, None)
            scalar_spec = P(*e_ax)
            # exponent bank replicated: the EP body slices its experts'
            # rows locally and still holds every expert's e_last for the
            # post-gather left-shift (no per-call exponent collective)
            exp_spec = None if dq.psum_exps is None else P()
            aw_spec = P(*e_ax, *(None,) * (dq.aw_exp.ndim - lead))
        else:
            w_spec = scalar_spec = aw_spec = P()
            exp_spec = None if dq.psum_exps is None else P()
    else:
        plan = plan_gemm(k=k, n=n, n_p=n_p, gs=gs, d=d)
        plan_axis, mode = plan.axis, plan.mode
        scalar_spec = P(*pad) if dq.ax_exp.ndim else P()
        aw_spec = P(*(None,) * dq.aw_exp.ndim)
        exp_spec = (None if dq.psum_exps is None
                    else P(*(None,) * dq.psum_exps.ndim))
        if plan_axis == "k" and k % d == 0:
            # PSQ tile spans / w8a8 column spans; ragged K (k % n_p != 0)
            # keeps replicated storage — execution pads and slices.
            w_spec = P(*pad, ax, None)
        elif plan_axis == "n":
            # exponent bank stays replicated even for per-column [n_p, N]
            # layers: the body slices its columns locally, and the full
            # e_last row finishes the code gather with no sidecar.
            w_spec = P(*pad, None, ax)
        else:
            w_spec = P(*pad, None, None)

    name = dq.name or f"dq{len(plans)}"
    plans[name] = LayerPlan(name=name, kind=kind, mode=mode, axis=plan_axis,
                            d=d, k=k, n=n, n_p=n_p, gs=gs, units=units,
                            experts=experts, per_col=per_col)
    return dataclasses.replace(
        dq,
        w_codes=_put(dq.w_codes, mesh, w_spec),
        ax_exp=_put(dq.ax_exp, mesh, scalar_spec),
        aw_exp=_put(dq.aw_exp, mesh, aw_spec),
        psum_exps=(None if dq.psum_exps is None
                   else _put(dq.psum_exps, mesh, exp_spec)),
    )


def shard_deployed(tree, mesh, *, model_axis: str = "model"):
    """Partition an exported param tree over ``mesh``'s model axis.

    Every ``DeployedQuantState`` is placed per ``plan_gemm`` (PSQ -> K by
    whole PSUM tiles, APSQ -> N, W8A8 -> K, MoE expert banks -> expert
    axis); float leaves (norms, router, embedding table) replicate.
    Returns ``(tree, plans)`` — the committed-device tree plus the
    ``{name: LayerPlan}`` wire report feeding ``dist_bench``.
    """
    plans: dict = {}

    def walk(node):
        if isinstance(node, DeployedQuantState):
            return _place_dq(node, "linear", mesh, model_axis, plans)
        if isinstance(node, dict):
            is_moe = "router" in node
            out = {}
            for key, v in node.items():
                if isinstance(v, DeployedQuantState):
                    kind = ("head" if key == "qp_head" else
                            "expert" if is_moe and key != "qp" else "linear")
                    out[key] = _place_dq(v, kind, mesh, model_axis, plans)
                else:
                    out[key] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        if node is None:
            return None
        return _put(node, mesh, P())

    return walk(tree), plans


def shard_paged_state(state, cfg, mesh, *, model_axis: str = "model"):
    """Place a paged decode state: KV pools shard over kv-heads on the
    model axis (``[n_pages, P, Hkv, hd]`` -> ``P(None, None, ax, None)``),
    running exponents ``[B, Hkv]`` follow, everything else replicates.

    Head sharding needs the axis to divide BOTH head counts (the attention
    shard_map splits q over Hq and the pools over Hkv); otherwise the
    whole state replicates and attention runs single-device semantics.
    Returns ``(state, plans)`` with one "attn" LayerPlan per attention
    layer for the (flag-invariant) fp32 head-gather accounting.
    """
    d = _mesh_dim(mesh, model_axis)
    shard_heads = (d > 1 and cfg.n_heads % d == 0 and cfg.n_kv_heads % d == 0)
    plans: dict = {}

    def spec(path, leaf):
        names = [str(getattr(p, "key", "")) for p in path]
        key = names[-1] if names else ""
        if shard_heads and key in ("k_pages", "v_pages"):
            if key == "k_pages":
                i = len(plans)
                plans[f"attn.{i}"] = LayerPlan(
                    name=f"attn.{i}", kind="attn", mode="-", axis="heads",
                    d=d, n=cfg.n_heads * cfg.hd)
            return P(*(None,) * (leaf.ndim - 2), model_axis, None)
        if shard_heads and key in ("k_exp", "v_exp"):
            return P(*(None,) * (leaf.ndim - 1), model_axis)
        return P()

    placed = jax.tree_util.tree_map_with_path(
        lambda path, leaf: _put(leaf, mesh, spec(path, leaf)), state)
    return placed, plans


# ---------------------------------------------------------------------------
# Collective GEMM bodies (called by repro.exec.ShardedBackend)
# ---------------------------------------------------------------------------


def _gather_codes(y_local: jax.Array, e_local: jax.Array, e_full: jax.Array,
                  e_is_col: bool, ax: str, axis: int) -> jax.Array:
    """Lossless INT8 gather of a PSUM-mode output along ``axis``.

    ``y_local`` is ``code << e_last`` by Algorithm-1 construction (code in
    [-128, 127]), so the arithmetic right-shift recovers the code exactly;
    ONLY 1-byte codes cross the wire — ``e_full`` is the replicated
    exponent bank's last row, already resident on every device, and the
    left-shift after the gather is exact.
    """
    eb = e_local
    if e_is_col:  # broadcast [.., N_loc] exps over the M rows
        eb = jnp.expand_dims(e_local, axis=-2)
    codes = jnp.right_shift(y_local, jnp.asarray(eb, jnp.int32))
    codes = jax.lax.all_gather(codes.astype(jnp.int8), ax,
                               axis=axis, tiled=True)
    ebf = jnp.expand_dims(e_full, -2) if e_is_col else e_full
    return jnp.left_shift(codes.astype(jnp.int32), jnp.asarray(ebf, jnp.int32))


def sharded_int_gemm(mesh, inner, x_codes, w_codes, psum_exps, *, gs: int,
                     model_axis: str = "model", wire: str = "int8"):
    """Mesh-parallel ``int_gemm`` with plan-directed sharding + combines.

    Bit-exact to ``inner.int_gemm`` on one device by construction: K-shards
    only ever move full-precision INT32 partials (or finished PO2-grid
    codes), N-shards only move finished codes.  ``wire="fp32"`` keeps the
    identical arithmetic but gathers 4-byte words — the parity-debugging
    fallback (and the baseline ``dist_bench`` prices).
    """
    m, k = int(x_codes.shape[0]), int(x_codes.shape[1])
    n = int(w_codes.shape[1])
    d = _mesh_dim(mesh, model_axis)
    n_p = None if psum_exps is None else int(psum_exps.shape[0])
    plan = plan_gemm(k=k, n=n, n_p=n_p, gs=gs, d=d)
    if not plan.sharded:
        return inner.int_gemm(x_codes, w_codes, psum_exps, gs=gs)
    ax = model_axis
    per_col = psum_exps is not None and psum_exps.ndim == 2

    if plan.axis == "n":
        nloc = n // d

        def body_n(xc, w_loc, e_full):
            # exponent bank arrives replicated; per-column layers slice
            # their own column span at trace time (free, no collective)
            if psum_exps is None:
                e_loc = None
            elif per_col:
                idx = jax.lax.axis_index(ax)
                e_loc = jax.lax.dynamic_slice_in_dim(
                    e_full, idx * nloc, nloc, axis=1)
            else:
                e_loc = e_full
            y = inner.int_gemm(xc, w_loc, e_loc, gs=gs)
            if psum_exps is None or wire == "fp32":
                return jax.lax.all_gather(y, ax, axis=1, tiled=True)
            return _gather_codes(y, e_loc[-1], e_full[-1], per_col,
                                 ax, axis=1)

        e_spec = (P() if psum_exps is None
                  else P(None, None) if per_col else P(None))
        e_arg = jnp.zeros((), jnp.int32) if psum_exps is None else psum_exps
        f = shard_map(body_n, mesh=mesh,
                      in_specs=(P(None, None), P(None, ax), e_spec),
                      out_specs=P(None, None), axis_names={ax})
        return f(x_codes, w_codes, e_arg)

    # K-sharded
    if plan.mode == "w8a8":
        def body_k8(x_loc, w_loc):
            part = inner.int_gemm(x_loc, w_loc, None, gs=1)
            return jax.lax.psum(part, ax)

        f = shard_map(body_k8, mesh=mesh,
                      in_specs=(P(None, ax), P(ax, None)),
                      out_specs=P(None, None), axis_names={ax})
        return f(x_codes, w_codes)

    # PSQ: whole-PSUM-tile spans.  Pad ragged K to n_p * kt first (the
    # zero-contribution remainder group lands in the LAST device's span).
    kt = -(-k // n_p)
    kpad = n_p * kt - k
    if kpad:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, kpad)))
        w_codes = jnp.pad(w_codes, ((0, kpad), (0, 0)))
    tpd = n_p // d
    scatter = wire == "int8" and n % d == 0

    def body_kpsq(x_loc, w_loc, exps):
        idx = jax.lax.axis_index(ax)
        xt = x_loc.reshape(m, tpd, kt).transpose(1, 0, 2)
        wt = w_loc.reshape(tpd, kt, n)
        tiles = inner.int_expert_gemm(xt, wt, None, gs=1)  # [tpd, M, N]
        e_loc = jax.lax.dynamic_slice_in_dim(exps, idx * tpd, tpd, axis=0)
        eb = (e_loc[:, None, :] if per_col else e_loc[:, None, None])
        q = dequantize_psum(quantize_psum(tiles, eb), eb)
        # The globally-final tile stays raw INT32 (Algorithm 1 quantizes
        # it only once, after the full accumulation).
        is_last = idx == d - 1
        tail = jnp.where(is_last, tiles[-1], q[-1])
        partial = tail + (q[:-1].sum(axis=0) if tpd > 1 else 0)
        e_last = exps[-1]
        if scatter:
            part = jax.lax.psum_scatter(partial, ax, scatter_dimension=1,
                                        tiled=True)
            nloc = n // d
            e_sl = (jax.lax.dynamic_slice_in_dim(e_last, idx * nloc, nloc, 0)
                    if per_col else e_last)
            codes = quantize_psum(part, e_sl)
            codes = jax.lax.all_gather(codes, ax, axis=1, tiled=True)
            return dequantize_psum(codes, e_last)
        total = jax.lax.psum(partial, ax)
        return dequantize_psum(quantize_psum(total, e_last), e_last)

    f = shard_map(body_kpsq, mesh=mesh,
                  in_specs=(P(None, ax), P(ax, None),
                            P(None, None) if per_col else P(None)),
                  out_specs=P(None, None), axis_names={ax})
    return f(x_codes, w_codes, psum_exps)


def sharded_int_expert_gemm(mesh, inner, x_codes, w_codes, psum_exps, *,
                            gs: int, model_axis: str = "model",
                            wire: str = "int8"):
    """Expert-parallel stacked GEMM: [E, C, K] @ [E, K, N] over ``model``.

    Activations are replicated over the model axis, so "dispatch" is a
    free slice of each device's expert rows; the EP combine gathers the
    per-expert outputs as INT8 codes (each expert's static ``e_last``) —
    the int8 all-to-all equivalent.  W8A8 expert banks gather INT32.
    """
    d = _mesh_dim(mesh, model_axis)
    n_exp = int(x_codes.shape[0])
    if d <= 1 or n_exp % d:
        return inner.int_expert_gemm(x_codes, w_codes, psum_exps, gs=gs)
    ax = model_axis
    epd = n_exp // d
    per_col = psum_exps is not None and psum_exps.ndim == 3

    def body(xc, wc, exps):
        # exps arrives replicated [E, n_p(, N)]; slice our expert rows —
        # every device keeps all experts' e_last for the combine below
        if psum_exps is None:
            e_loc = None
        else:
            idx = jax.lax.axis_index(ax)
            e_loc = jax.lax.dynamic_slice_in_dim(exps, idx * epd, epd,
                                                 axis=0)
        y = inner.int_expert_gemm(xc, wc, e_loc, gs=gs)
        if psum_exps is None or wire == "fp32":
            return jax.lax.all_gather(y, ax, axis=0, tiled=True)
        e_last = e_loc[:, -1]                     # [E_loc] or [E_loc, N]
        eb = (e_last[:, None, :] if per_col else e_last[:, None, None])
        codes = jnp.right_shift(y, jnp.asarray(eb, jnp.int32))
        codes = jax.lax.all_gather(codes.astype(jnp.int8), ax,
                                   axis=0, tiled=True)
        ef = exps[:, -1]                          # full e_last: resident
        ebf = (ef[:, None, :] if per_col else ef[:, None, None])
        return jnp.left_shift(codes.astype(jnp.int32),
                              jnp.asarray(ebf, jnp.int32))

    e_spec = P() if psum_exps is None else P(*(None,) * psum_exps.ndim)
    e_arg = jnp.zeros((), jnp.int32) if psum_exps is None else psum_exps
    f = shard_map(body, mesh=mesh,
                  in_specs=(P(ax, None, None), P(ax, None, None), e_spec),
                  out_specs=P(None, None, None), axis_names={ax})
    return f(x_codes, w_codes, e_arg)


def sharded_kv_attention(mesh, inner, q, k_codes, v_codes, k_exp, v_exp,
                         length, *, block_s: int,
                         model_axis: str = "model"):
    """Head-parallel paged attention: split Hq/Hkv over the model axis.

    Attention never mixes heads, so each device attends its own head
    slice against its slice of the INT8 pools — no collective at all;
    the (fp32) head gather happens downstream when the out-projection
    needs the full feature row, and is priced by the "attn" LayerPlans.
    The output stays logically full, physically head-sharded.
    """
    d = _mesh_dim(mesh, model_axis)
    hq = int(q.shape[-2])
    hkv = int(k_codes.shape[2])
    if d <= 1 or hq % d or hkv % d:
        return inner.kv_attention(q, k_codes, v_codes, k_exp, v_exp, length,
                                  block_s=block_s)
    ax = model_axis
    q_spec = (P(None, None, ax, None) if q.ndim == 4 else P(None, ax, None))

    def body(ql, kc, vc, ke, ve, ln):
        return inner.kv_attention(ql, kc, vc, ke, ve, ln, block_s=block_s)

    f = shard_map(body, mesh=mesh,
                  in_specs=(q_spec, P(None, None, ax, None),
                            P(None, None, ax, None), P(None, ax),
                            P(None, ax), P(None)),
                  out_specs=q_spec, axis_names={ax})
    return f(q, k_codes, v_codes, k_exp, v_exp, length)
