"""Sharded checkpoints with manifest, async save, reshard-on-load.

Fault-tolerance posture for 1000+ nodes:
  * every leaf is written as its own ``.npy`` under a step directory with a
    JSON manifest (tree structure, shapes, dtypes, step metadata) — on a
    real cluster each host writes only the shards it owns; here the single
    process writes everything (same layout);
  * writes go to ``<dir>/tmp-<step>`` then atomically ``rename`` to
    ``step-<n>`` so a crash mid-save never corrupts the latest checkpoint;
  * ``save_async`` copies to host memory synchronously (cheap) and writes
    in a background thread, so the train loop is blocked only for the
    device->host transfer, not the filesystem;
  * ``restore`` takes an optional ``shardings`` tree and ``jax.device_put``s
    each leaf with the *current* mesh's sharding — elastic restart onto a
    different pod count reshards transparently;
  * quantizer state (``repro.core.QuantState``) round-trips: data fields
    are written as ordinary leaves and the static spec/name metadata goes
    into the manifest (``quant_states``), so ``restore`` rebuilds typed
    states; pre-API-v2 checkpoints (raw ``{"aw","ax","ap"}`` dicts under
    ``qp`` keys) are upgraded on load when a ``quant_policy`` is passed;
  * emergency checkpoints: ``install_signal_handler`` saves on SIGTERM
    (preemption) before re-raising.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import signal
import threading

import jax
import numpy as np

from repro.core import (DeployedQuantState, PsumQuantConfig, QuantConfig,
                        QuantState)

_SEP = "/"


def _spec_to_json(spec: QuantConfig | None):
    if spec is None:
        return None
    d = dataclasses.asdict(spec)
    return d


def _spec_from_json(d) -> QuantConfig | None:
    if d is None:
        return None
    # read-only: ``d`` aliases manifest["quant_states"][...]["spec"], which
    # restore() hands back to the caller intact
    psum = PsumQuantConfig(**d["psum"])
    rest = {k: v for k, v in d.items() if k != "psum"}
    return QuantConfig(psum=psum, **rest)


def _flatten(tree, prefix="", quant_meta: dict | None = None):
    out = {}
    if isinstance(tree, QuantState):
        if quant_meta is not None:
            quant_meta[prefix] = {"kind": "QuantState",
                                  "spec": _spec_to_json(tree.spec),
                                  "name": tree.name}
        return _flatten(tree.as_dict(), prefix, quant_meta)
    if isinstance(tree, DeployedQuantState):
        if quant_meta is not None:
            quant_meta[prefix] = {"kind": "DeployedQuantState",
                                  "spec": _spec_to_json(tree.spec),
                                  "name": tree.name,
                                  "out_dims": list(tree.out_dims)}
        d = {"w_codes": tree.w_codes, "ax_exp": tree.ax_exp,
             "aw_exp": tree.aw_exp}
        if tree.psum_exps is not None:
            d["psum_exps"] = tree.psum_exps
        return _flatten(d, prefix, quant_meta)
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else k,
                                quant_meta))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}{i}" if prefix else str(i),
                                quant_meta))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def _tree_get(tree, parts):
    for p in parts:
        if not isinstance(tree, dict) or p not in tree:
            return None
        tree = tree[p]
    return tree


def _tree_set(tree, parts, value):
    node = tree
    for p in parts[:-1]:
        node = node[p]
    node[parts[-1]] = value


def _reify_quant_states(tree: dict, quant_meta: dict) -> dict:
    """Rebuild typed quantizer nodes recorded in the manifest (in place)."""
    for path, meta in quant_meta.items():
        parts = path.split(_SEP)
        node = _tree_get(tree, parts)
        if not isinstance(node, dict):
            continue
        kind = meta.get("kind", "QuantState")
        if kind == "DeployedQuantState" and "w_codes" in node:
            _tree_set(tree, parts, DeployedQuantState(
                w_codes=node["w_codes"], ax_exp=node["ax_exp"],
                aw_exp=node["aw_exp"], psum_exps=node.get("psum_exps"),
                spec=_spec_from_json(meta["spec"]),
                name=meta.get("name", ""),
                out_dims=tuple(meta.get("out_dims", ()))))
        elif "aw" in node and "ax" in node:
            _tree_set(tree, parts, QuantState.from_dict(
                node, spec=_spec_from_json(meta["spec"]),
                name=meta.get("name", "")))
    return tree


_MODEL_ROOTS = ("units", "rem", "encoder", "head", "frontend_proj")


def _legacy_layer_name(parts) -> str:
    """Map an old checkpoint path to the API-v2 stable layer name.

    ``params/units/u0/1/mix/wq/qp`` -> ``unit.1.mix.wq``;
    ``opt/m/rem/0/ffn/wi/qp``       -> ``rem.0.ffn.wi``.

    Leading container segments (``params``, ``opt/m``, ``opt/v``, ...)
    are stripped up to the first model root so the optimizer-moment
    mirrors of a quantizer get the *same* name/spec as the param itself —
    the metadata is treedef aux data, and jax.tree.map over (params,
    moments) requires identical treedefs.
    """
    parts = [p for p in parts if p != "qp"]
    for i, p in enumerate(parts):
        if p in _MODEL_ROOTS:
            parts = parts[i:]
            break
    out = []
    i = 0
    while i < len(parts):
        p = parts[i]
        if p == "units":
            out.append("unit")
            nxt = parts[i + 1] if i + 1 < len(parts) else ""
            if nxt.startswith("u") and nxt[1:].isdigit():
                i += 1  # drop the per-unit index: names are per position
        else:
            out.append(p)
        i += 1
    return ".".join(out)


_DROP = object()

# Legacy layer names whose quantizer state was vestigial: old
# init_rwkv_channel_mix created qp for the sigmoid gate ``wr`` although the
# apply path always ran it unquantized (API v2 no longer creates it).
# Upgrading it would silently start quantizing the gate AND give the
# restored tree a different treedef than a fresh v2 init, so drop it.
_LEGACY_VESTIGIAL_SUFFIXES = (".ffn.wr",)


def _upgrade_legacy_quant(tree, quant_policy):
    """Wrap pre-v2 ``{"aw","ax","ap"}`` dicts into typed ``QuantState``s,
    resolving each layer's spec from ``quant_policy`` by its path-derived
    name (``quant_policy`` may be a QuantPolicy or a plain QuantConfig)."""
    def resolve(name):
        if hasattr(quant_policy, "resolve"):
            return quant_policy.resolve(name)
        return quant_policy

    def walk(node, parts):
        if not isinstance(node, dict):
            return node
        if (set(node) <= {"aw", "ax", "ap"} and "aw" in node and "ax" in node
                and parts and parts[-1].startswith("qp")):
            name = _legacy_layer_name(list(parts[:-1])
                                      + ([parts[-1][3:]]
                                         if parts[-1].startswith("qp_")
                                         else []))
            if name.endswith(_LEGACY_VESTIGIAL_SUFFIXES):
                return _DROP
            return QuantState.from_dict(node, spec=resolve(name), name=name)
        out = {}
        for k, v in node.items():
            r = walk(v, parts + (k,))
            if r is not _DROP:
                out[k] = r
        return out

    return walk(tree, ())


def _key_to_fname(key: str) -> str:
    return key.replace(_SEP, "__") + ".npy"


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic checkpoint save; returns the final path."""
    quant_meta: dict = {}
    flat = _flatten(tree, quant_meta=quant_meta)
    tmp = os.path.join(ckpt_dir, f"tmp-{step}")
    final = os.path.join(ckpt_dir, f"step-{step:09d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "extra": extra or {}, "leaves": {},
                "quant_states": quant_meta}
    for key, val in flat.items():
        arr = np.asarray(val)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
        np.save(os.path.join(tmp, _key_to_fname(key)), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Device->host copy synchronously; filesystem write off-thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()  # one in flight at a time
        host_tree = jax.tree.map(np.asarray, tree)  # blocks on device only

        def _write():
            save(self.ckpt_dir, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step-{s:09d}"),
                          ignore_errors=True)


def list_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step-"):
            out.append(int(name.split("-")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int | None = None,
            shardings=None, quant_policy=None) -> tuple:
    """Load a checkpoint; returns (tree, manifest).

    ``shardings``: optional tree (same structure) of NamedSharding/Sharding;
    each leaf is device_put with it — reshard-on-load for elastic restart.
    ``quant_policy``: back-compat shim for pre-API-v2 checkpoints — a
    QuantPolicy (or QuantConfig) used to upgrade raw ``{"aw","ax","ap"}``
    quantizer dicts into typed ``QuantState``s with resolved per-layer
    specs.  Checkpoints written by API v2 carry their quantizer metadata
    in the manifest and need no policy.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step-{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_shardings = (_flatten(shardings) if shardings is not None else {})
    flat = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, _key_to_fname(key)))
        # numpy round-trips ml_dtypes (bfloat16/int4) as raw void records;
        # reinterpret through the manifest dtype.
        if str(arr.dtype) != meta["dtype"]:
            import jax.numpy as jnp
            arr = arr.view(jnp.dtype(meta["dtype"]))
        sh = flat_shardings.get(key)
        flat[key] = jax.device_put(arr, sh) if sh is not None else arr
    tree = _unflatten(flat)
    quant_meta = manifest.get("quant_states") or {}
    if quant_meta:
        tree = _reify_quant_states(tree, quant_meta)
    elif quant_policy is not None:
        tree = _upgrade_legacy_quant(tree, quant_policy)
    return tree, manifest


def install_signal_handler(checkpointer: AsyncCheckpointer, get_state):
    """Emergency checkpoint on SIGTERM (preemption notice), then re-raise."""
    def handler(signum, frame):
        step, tree = get_state()
        save(checkpointer.ckpt_dir, step, jax.tree.map(np.asarray, tree),
             {"emergency": True})
        signal.signal(signum, signal.SIG_DFL)
        os.kill(os.getpid(), signum)

    signal.signal(signal.SIGTERM, handler)
