#!/usr/bin/env bash
# CI entrypoint.
#
#   scripts/ci.sh                 tier-1: full test suite (extra args -> pytest)
#   scripts/ci.sh kernel-backend  interpret-mode kernel-backend job: the
#                                 kernel-vs-oracle parity grid + exec-backend
#                                 tests + a kernel_bench --smoke pass, so
#                                 kernel regressions fail fast and in
#                                 isolation from the (slower) tier-1 run.
#
# Collection regressions (missing modules, import errors) fail the run
# because pytest errors out before running a single test.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install --quiet -r requirements-dev.txt
python -m pip install --quiet "jax>=0.4.30" numpy 2>/dev/null || true

if [[ "${1:-}" == "kernel-backend" ]]; then
    shift
    python -m pytest -q tests/test_kernels.py tests/test_exec.py "$@"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.kernel_bench --smoke
else
    python -m pytest -x -q "$@"
fi
