"""Pallas TPU kernel: W8A8 GEMM with INT8 additive-partial-sum banks.

TPU-native adaptation of the paper's Reconfigurable APSQ Engine (RAE):

  * the grid's K dimension IS the PSUM tiling — one grid step per PSUM tile
    ``T_pi`` (``n_p = K / block_k``, the paper's ``ceil(C_i / P_ci)``),
  * the RAE's four PSUM SRAM banks become a ``[gs, bm, bn]`` INT8 VMEM
    scratch — the running accumulator lives at 1 byte/element instead of the
    4 bytes/element an INT32 accumulator needs (the paper's beta: 4 -> 1),
  * quant/dequant are shift operations (power-of-two scales), matching the
    RAE's shifter modules: ``quantize = clip((v + 2^(e-1)) >> e)``,
    ``dequantize = code << e``,
  * the RAE's s0/s1/s2 mux encodings become compile-time specialization on
    the static ``gs`` — each group size compiles its own kernel, which is
    the TPU-idiomatic form of "reconfigurability".

Grid: ``(M/bm, N/bn, n_p)`` with the K dimension sequential ("arbitrary")
so the banks persist across PSUM tiles of one output tile.  Block specs put
x/w/out tiles in VMEM; the per-tile shift exponents sit in SMEM.

Three launch geometries share the Algorithm-1 body:

  * the generic grid above (``apsq_matmul_kernel``),
  * the m=1 decode fast path (``apsq_matmul_m1_kernel``) — grid ``(N/bn,)``
    with the whole K row resident in VMEM and the PSUM recurrence unrolled
    in-register (no bank scratch, no K grid steps: single-token decode is
    grid-overhead-bound, not compute-bound),
  * the fused MoE expert grid (``apsq_expert_matmul_kernel``) — grid
    ``(E, M/bm, N/bn, n_p)`` so ONE ``pallas_call`` serves every expert of
    a stacked ``DeployedQuantState`` bank, with each expert's exponent
    bank indexed by the leading grid coordinate.

Block sizes come from ``repro.kernels.autotune`` (per-shape-class cached
winners with a static heuristic fallback) unless the caller pins them.

Validated bit-exact against ``ref.apsq_matmul_ref`` in interpret mode
(tests/test_kernels.py sweeps shapes, gs, n_p and adversarial exponents).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT8_MIN, INT8_MAX = -128, 127


def _rshift_round(v, e):
    """(v + 2^(e-1)) >> e with e >= 0 (e may be traced)."""
    e = jnp.asarray(e, jnp.int32)
    bias = jnp.where(e > 0, jnp.left_shift(1, jnp.maximum(e - 1, 0)), 0)
    return jnp.where(e > 0, jnp.right_shift(v + bias, e), v)


def _quantize(v, e):
    return jnp.clip(_rshift_round(v, e), INT8_MIN, INT8_MAX).astype(jnp.int8)


def _dequantize(code, e):
    return jnp.left_shift(code.astype(jnp.int32), jnp.asarray(e, jnp.int32))


def _read_exp(exp_ref, i, *, col0=None, block_n=None):
    """Shift exponent(s) for PSUM tile ``i`` (static int or program_id).

    1-D exps ([n_p] in SMEM): scalar per tile — per-tensor weight scales.
    2-D exps in VMEM: one exponent row per tile — the per-channel export
    layout (``psum_exps[:, N]``); the [1, bn] row broadcasts over the
    [bm, bn] accumulator in the shift helpers.  With the "blocked" layout
    the ref already holds this tile's [n_p, block_n] column slice; with
    the "full" layout (``col0`` given) the whole [n_p, N] table is
    resident and the column window is sliced dynamically.
    """
    if len(exp_ref.shape) == 2:
        if col0 is not None:
            return exp_ref[pl.dslice(i, 1), pl.dslice(col0, block_n)]
        return exp_ref[pl.dslice(i, 1), :]
    return exp_ref[i]


def _algorithm1_unrolled(prod, exp, *, n_p: int, gs: int):
    """Algorithm 1 over statically-unrolled PSUM tiles, fully in-register.

    ``prod(i)`` yields the INT32 partial-sum tile ``i``; ``exp(i)`` its
    shift exponent(s).  Mirrors ``ref.apsq_matmul_ref`` tile for tile
    (group starts fold the previous group's codes, tails are plain PSQ,
    the final tile requantizes once more) with Python control flow only —
    n_p and gs are static, so the whole recurrence unrolls.  Used by the
    m=1 fast path where tiles are column slices of one resident K row.
    """
    stored: list = [None] * n_p
    for i in range(0, n_p, gs):
        acc = prod(i)
        for j in range(max(0, i - gs), i):
            acc = acc + _dequantize(stored[j], exp(j))
        code = _quantize(acc, exp(i))
        stored[i] = code
        if i == n_p - 1:
            return _dequantize(code, exp(i))
        for j in range(i + 1, min(i + gs, n_p)):
            if j < n_p - 1:
                stored[j] = _quantize(prod(j), exp(j))
            else:  # final tile closes out mid-group
                acc = prod(j)
                for l in range(i, n_p - 1):
                    acc = acc + _dequantize(stored[l], exp(l))
                code = _quantize(acc, exp(j))
                return _dequantize(code, exp(j))
    raise AssertionError("unreachable")


def _apsq_kernel(exp_ref, x_ref, w_ref, out_ref, banks_ref, *, n_p: int,
                 gs: int, exp_layout: str = "blocked", block_n: int = 0):
    """One grid step = one PSUM tile T_pk of one (i, j) output tile."""
    k = pl.program_id(2)
    col0 = pl.program_id(1) * block_n if exp_layout == "full" else None
    exp = functools.partial(_read_exp, exp_ref, col0=col0, block_n=block_n)
    prod = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # int8 x int8 -> int32 on the MXU

    if n_p == 1:
        # Single PSUM tile: output quantization only (Algorithm 1 line 2).
        out_ref[...] = _dequantize(_quantize(prod, exp(0)), exp(0))
        return

    last = n_p - 1
    last_start = (last // gs) * gs

    @pl.when(k == 0)
    def _first():  # AP*_0 = Q_0(T_p0)
        banks_ref[0] = _quantize(prod, exp(0))

    @pl.when((k > 0) & (k % gs == 0) & (k < last))
    def _group_start():  # APSQ: fold the previous group's banks back in
        acc = prod
        for j in range(gs):  # bank j holds tile (k - gs + j)
            acc = acc + _dequantize(banks_ref[j], exp(k - gs + j))
        banks_ref[0] = _quantize(acc, exp(k))

    @pl.when((k > 0) & (k % gs != 0) & (k < last))
    def _tail():  # plain PSQ on a tail tile
        code = _quantize(prod, exp(k))
        pl.store(banks_ref, (pl.dslice(k % gs, 1), slice(None), slice(None)),
                 code[None])

    @pl.when(k == last)
    def _final():
        # Statically known: which banks are live and their tile indices.
        acc = prod
        if last % gs == 0:  # final tile is itself a group start -> APSQ
            if last > 0:
                for j in range(gs):
                    acc = acc + _dequantize(banks_ref[j], exp(last - gs + j))
        else:  # mid-group: fold the stored tiles since last_start
            for l in range(last_start, last):
                acc = acc + _dequantize(banks_ref[l - last_start], exp(l))
        out_ref[...] = _dequantize(_quantize(acc, exp(last)), exp(last))


def _baseline_kernel(x_ref, w_ref, out_ref, acc_ref, *, n_p: int):
    """INT32-accumulator W8A8 GEMM — the high-precision-PSUM baseline.

    Identical grid/blocking, but the running PSUM is a [bm, bn] INT32 VMEM
    scratch: 4 bytes/element, the paper's beta = 4 working set.
    """
    k = pl.program_id(2)
    prod = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = prod

    @pl.when(k > 0)
    def _acc():
        acc_ref[...] = acc_ref[...] + prod

    @pl.when(k == n_p - 1)
    def _out():
        out_ref[...] = acc_ref[...] if n_p > 1 else prod


def _make_params(sem: tuple):
    try:
        return pltpu.CompilerParams(dimension_semantics=sem)
    except AttributeError:  # older jax
        return pltpu.TPUCompilerParams(dimension_semantics=sem)


def _compiler_params(n_dims: int):
    """dimension_semantics: M/N parallel, K sequential (banks carry state)."""
    return _make_params(("parallel",) * (n_dims - 1) + ("arbitrary",))


def _parallel_params(n_dims: int):
    """All-parallel semantics (no cross-step state — the m=1 fast path)."""
    return _make_params(("parallel",) * n_dims)


@functools.partial(
    jax.jit,
    static_argnames=("gs", "block_m", "block_n", "n_p", "exp_layout",
                     "interpret"),
)
def apsq_matmul_kernel(
    x_codes: jax.Array,
    w_codes: jax.Array,
    exps: jax.Array,
    *,
    n_p: int,
    gs: int,
    block_m: int = 128,
    block_n: int = 128,
    exp_layout: str = "blocked",
    interpret: bool = False,
) -> jax.Array:
    """[M, K] int8 @ [K, N] int8 -> [M, N] int32 (product-scale units).

    ``M % block_m == 0``, ``N % block_n == 0``, ``K % n_p == 0`` — the ops.py
    wrapper pads.  ``exps`` is int32, exponents >= 0: [n_p] (per-tensor
    weight scales; SMEM scalars) or [n_p, N] (per-channel export layout).
    ``exp_layout`` picks how 2-D exponents reach VMEM: "blocked" streams a
    [n_p, block_n] column slice per output tile, "full" keeps the whole
    [n_p, N] table resident and slices dynamically (an autotunable axis).
    """
    m, kdim = x_codes.shape
    n = w_codes.shape[1]
    assert kdim % n_p == 0 and m % block_m == 0 and n % block_n == 0
    if exps.ndim == 2:
        assert exps.shape == (n_p, n), (exps.shape, n_p, n)
        if exp_layout == "full":
            exp_spec = pl.BlockSpec((n_p, n), lambda i, j, k: (0, 0))
        else:
            exp_spec = pl.BlockSpec((n_p, block_n), lambda i, j, k: (0, j))
    else:
        exp_layout = "blocked"  # layout only matters for 2-D exps
        exp_spec = pl.BlockSpec(memory_space=pltpu.SMEM)  # [n_p] scalars
    block_k = kdim // n_p

    grid = (m // block_m, n // block_n, n_p)
    return pl.pallas_call(
        functools.partial(_apsq_kernel, n_p=n_p, gs=gs,
                          exp_layout=exp_layout, block_n=block_n),
        grid=grid,
        in_specs=[
            exp_spec,
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((gs, block_m, block_n), jnp.int8)],
        compiler_params=_compiler_params(3),
        interpret=interpret,
    )(exps, x_codes, w_codes)


# ---------------------------------------------------------------------------
# m=1 decode fast path
# ---------------------------------------------------------------------------

def _apsq_m1_kernel(exp_ref, x_ref, w_ref, out_ref, *, n_p: int, gs: int,
                    block_k: int):
    """Single-token decode: one grid step per N tile, K unrolled in-register.

    ``x_ref`` holds the whole [1, K] code row, ``w_ref`` this tile's
    [K, block_n] column slab; PSUM tile ``i`` is a static column slice, so
    the Algorithm-1 recurrence runs fully unrolled with no bank scratch
    and no K grid steps — the decode shape is launch-overhead-bound, and
    this removes the n_p-step grid walk the generic kernel pays.
    """
    def prod(i):
        xs = x_ref[:, i * block_k:(i + 1) * block_k]
        ws = w_ref[i * block_k:(i + 1) * block_k, :]
        return jax.lax.dot_general(
            xs, ws, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    exp = functools.partial(_read_exp, exp_ref)
    out_ref[...] = _algorithm1_unrolled(prod, exp, n_p=n_p, gs=gs)


@functools.partial(
    jax.jit, static_argnames=("gs", "block_n", "n_p", "interpret"))
def apsq_matmul_m1_kernel(
    x_codes: jax.Array,
    w_codes: jax.Array,
    exps: jax.Array,
    *,
    n_p: int,
    gs: int,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """[1, K] int8 @ [K, N] int8 -> [1, N] int32 — the decode fast path.

    Same Algorithm-1 semantics as ``apsq_matmul_kernel`` (bit-exact), but
    grid ``(N/bn,)`` with the K reduction inlined per tile.  ``K % n_p``
    and ``N % block_n`` must be 0 (ops.py pads).
    """
    m, kdim = x_codes.shape
    n = w_codes.shape[1]
    assert m == 1 and kdim % n_p == 0 and n % block_n == 0
    if exps.ndim == 2:
        assert exps.shape == (n_p, n), (exps.shape, n_p, n)
        exp_spec = pl.BlockSpec((n_p, block_n), lambda j: (0, j))
    else:
        exp_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    block_k = kdim // n_p

    return pl.pallas_call(
        functools.partial(_apsq_m1_kernel, n_p=n_p, gs=gs, block_k=block_k),
        grid=(n // block_n,),
        in_specs=[
            exp_spec,
            pl.BlockSpec((1, kdim), lambda j: (0, 0)),
            pl.BlockSpec((kdim, block_n), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        compiler_params=_parallel_params(1),
        interpret=interpret,
    )(exps, x_codes, w_codes)


# ---------------------------------------------------------------------------
# Fused MoE expert grid
# ---------------------------------------------------------------------------

def _apsq_expert_kernel(exp_ref, x_ref, w_ref, out_ref, banks_ref, *,
                        n_p: int, gs: int):
    """One grid step = one PSUM tile of one (e, i, j) expert output tile.

    Identical Algorithm-1 body to ``_apsq_kernel``; the refs carry a
    leading singleton expert dim selected by grid coordinate 0, and the
    exponent read indexes that expert's bank.
    """
    k = pl.program_id(3)

    if len(exp_ref.shape) == 3:  # [1, n_p, block_n] — this expert's bank
        exp = lambda i: exp_ref[0, pl.dslice(i, 1), :]
    else:  # [E, n_p] whole table in SMEM
        e = pl.program_id(0)
        exp = lambda i: exp_ref[e, i]
    prod = jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    if n_p == 1:
        out_ref[0] = _dequantize(_quantize(prod, exp(0)), exp(0))
        return

    last = n_p - 1
    last_start = (last // gs) * gs

    @pl.when(k == 0)
    def _first():
        banks_ref[0] = _quantize(prod, exp(0))

    @pl.when((k > 0) & (k % gs == 0) & (k < last))
    def _group_start():
        acc = prod
        for j in range(gs):
            acc = acc + _dequantize(banks_ref[j], exp(k - gs + j))
        banks_ref[0] = _quantize(acc, exp(k))

    @pl.when((k > 0) & (k % gs != 0) & (k < last))
    def _tail():
        code = _quantize(prod, exp(k))
        pl.store(banks_ref, (pl.dslice(k % gs, 1), slice(None), slice(None)),
                 code[None])

    @pl.when(k == last)
    def _final():
        acc = prod
        if last % gs == 0:
            if last > 0:
                for j in range(gs):
                    acc = acc + _dequantize(banks_ref[j], exp(last - gs + j))
        else:
            for l in range(last_start, last):
                acc = acc + _dequantize(banks_ref[l - last_start], exp(l))
        out_ref[0] = _dequantize(_quantize(acc, exp(last)), exp(last))


def _baseline_expert_kernel(x_ref, w_ref, out_ref, acc_ref, *, n_p: int):
    """INT32-accumulator W8A8 expert GEMM on the fused (E, i, j, k) grid."""
    k = pl.program_id(3)
    prod = jax.lax.dot_general(
        x_ref[0], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = prod

    @pl.when(k > 0)
    def _acc():
        acc_ref[...] = acc_ref[...] + prod

    @pl.when(k == n_p - 1)
    def _out():
        out_ref[0] = acc_ref[...] if n_p > 1 else prod


@functools.partial(
    jax.jit,
    static_argnames=("gs", "block_m", "block_n", "n_p", "interpret"),
)
def apsq_expert_matmul_kernel(
    x_codes: jax.Array,
    w_codes: jax.Array,
    exps: jax.Array,
    *,
    n_p: int,
    gs: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """[E, M, K] int8 @ [E, K, N] int8 -> [E, M, N] int32, one launch.

    The expert axis is grid dimension 0 — every expert of a stacked MoE
    ``DeployedQuantState`` bank is served by this single ``pallas_call``,
    with per-expert exponent banks ([E, n_p] in SMEM or [E, n_p, N]
    streamed per column tile) selected by the grid coordinate.  Dims
    follow the generic kernel's contract per expert (ops.py pads).
    """
    n_e, m, kdim = x_codes.shape
    n = w_codes.shape[2]
    assert kdim % n_p == 0 and m % block_m == 0 and n % block_n == 0
    if exps.ndim == 3:
        assert exps.shape == (n_e, n_p, n), (exps.shape, n_e, n_p, n)
        exp_spec = pl.BlockSpec((1, n_p, block_n),
                                lambda e, i, j, k: (e, 0, j))
    else:
        assert exps.shape == (n_e, n_p), (exps.shape, n_e, n_p)
        exp_spec = pl.BlockSpec(memory_space=pltpu.SMEM)  # whole [E, n_p]
    block_k = kdim // n_p

    grid = (n_e, m // block_m, n // block_n, n_p)
    return pl.pallas_call(
        functools.partial(_apsq_expert_kernel, n_p=n_p, gs=gs),
        grid=grid,
        in_specs=[
            exp_spec,
            pl.BlockSpec((1, block_m, block_k),
                         lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((n_e, m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((gs, block_m, block_n), jnp.int8)],
        compiler_params=_compiler_params(4),
        interpret=interpret,
    )(exps, x_codes, w_codes)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "n_p", "interpret"))
def baseline_expert_matmul_kernel(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    n_p: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """INT32-accumulator W8A8 expert GEMM — fused (E, i, j, k) grid."""
    n_e, m, kdim = x_codes.shape
    n = w_codes.shape[2]
    assert kdim % n_p == 0 and m % block_m == 0 and n % block_n == 0
    block_k = kdim // n_p

    grid = (n_e, m // block_m, n // block_n, n_p)
    return pl.pallas_call(
        functools.partial(_baseline_expert_kernel, n_p=n_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_k),
                         lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((n_e, m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_compiler_params(4),
        interpret=interpret,
    )(x_codes, w_codes)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "n_p", "interpret")
)
def baseline_matmul_kernel(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    n_p: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """INT32-accumulator W8A8 GEMM with the same grid/blocking as APSQ."""
    m, kdim = x_codes.shape
    n = w_codes.shape[1]
    assert kdim % n_p == 0 and m % block_m == 0 and n % block_n == 0
    block_k = kdim // n_p

    grid = (m // block_m, n // block_n, n_p)
    return pl.pallas_call(
        functools.partial(_baseline_kernel, n_p=n_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_compiler_params(3),
        interpret=interpret,
    )(x_codes, w_codes)


def accumulator_vmem_bytes(block_m: int, block_n: int, gs: int) -> dict:
    """Accumulator working-set per output tile: APSQ banks vs INT32 baseline.

    This is the co-design win on TPU: beta 4 -> gs/4 of the baseline bytes
    (gs=1: 4x smaller; gs=4: parity in VMEM but still 4x fewer bytes per
    HBM spill in split-K schedules, since only one bank is in flight).
    """
    return {
        "apsq_banks": gs * block_m * block_n,          # gs INT8 banks
        "baseline_int32": 4 * block_m * block_n,        # one INT32 accum
    }
