"""Pallas TPU kernel: W8A8 GEMM with INT8 additive-partial-sum banks.

TPU-native adaptation of the paper's Reconfigurable APSQ Engine (RAE):

  * the grid's K dimension IS the PSUM tiling — one grid step per PSUM tile
    ``T_pi`` (``n_p = K / block_k``, the paper's ``ceil(C_i / P_ci)``),
  * the RAE's four PSUM SRAM banks become a ``[gs, bm, bn]`` INT8 VMEM
    scratch — the running accumulator lives at 1 byte/element instead of the
    4 bytes/element an INT32 accumulator needs (the paper's beta: 4 -> 1),
  * quant/dequant are shift operations (power-of-two scales), matching the
    RAE's shifter modules: ``quantize = clip((v + 2^(e-1)) >> e)``,
    ``dequantize = code << e``,
  * the RAE's s0/s1/s2 mux encodings become compile-time specialization on
    the static ``gs`` — each group size compiles its own kernel, which is
    the TPU-idiomatic form of "reconfigurability".

Grid: ``(M/bm, N/bn, n_p)`` with the K dimension sequential ("arbitrary")
so the banks persist across PSUM tiles of one output tile.  Block specs put
x/w/out tiles in VMEM; the per-tile shift exponents sit in SMEM.

Validated bit-exact against ``ref.apsq_matmul_ref`` in interpret mode
(tests/test_kernels.py sweeps shapes, gs, n_p and adversarial exponents).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT8_MIN, INT8_MAX = -128, 127


def _rshift_round(v, e):
    """(v + 2^(e-1)) >> e with e >= 0 (e may be traced)."""
    e = jnp.asarray(e, jnp.int32)
    bias = jnp.where(e > 0, jnp.left_shift(1, jnp.maximum(e - 1, 0)), 0)
    return jnp.where(e > 0, jnp.right_shift(v + bias, e), v)


def _quantize(v, e):
    return jnp.clip(_rshift_round(v, e), INT8_MIN, INT8_MAX).astype(jnp.int8)


def _dequantize(code, e):
    return jnp.left_shift(code.astype(jnp.int32), jnp.asarray(e, jnp.int32))


def _read_exp(exp_ref, i):
    """Shift exponent(s) for PSUM tile ``i`` (static int or program_id).

    1-D exps ([n_p] in SMEM): scalar per tile — per-tensor weight scales.
    2-D exps ([n_p, block_n] in VMEM): one exponent row per tile — the
    per-channel export layout (``psum_exps[:, N]``); the [1, bn] row
    broadcasts over the [bm, bn] accumulator in the shift helpers.
    """
    if len(exp_ref.shape) == 2:
        return exp_ref[pl.dslice(i, 1), :]
    return exp_ref[i]


def _apsq_kernel(exp_ref, x_ref, w_ref, out_ref, banks_ref, *, n_p: int, gs: int):
    """One grid step = one PSUM tile T_pk of one (i, j) output tile."""
    k = pl.program_id(2)
    exp = functools.partial(_read_exp, exp_ref)
    prod = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )  # int8 x int8 -> int32 on the MXU

    if n_p == 1:
        # Single PSUM tile: output quantization only (Algorithm 1 line 2).
        out_ref[...] = _dequantize(_quantize(prod, exp(0)), exp(0))
        return

    last = n_p - 1
    last_start = (last // gs) * gs

    @pl.when(k == 0)
    def _first():  # AP*_0 = Q_0(T_p0)
        banks_ref[0] = _quantize(prod, exp(0))

    @pl.when((k > 0) & (k % gs == 0) & (k < last))
    def _group_start():  # APSQ: fold the previous group's banks back in
        acc = prod
        for j in range(gs):  # bank j holds tile (k - gs + j)
            acc = acc + _dequantize(banks_ref[j], exp(k - gs + j))
        banks_ref[0] = _quantize(acc, exp(k))

    @pl.when((k > 0) & (k % gs != 0) & (k < last))
    def _tail():  # plain PSQ on a tail tile
        code = _quantize(prod, exp(k))
        pl.store(banks_ref, (pl.dslice(k % gs, 1), slice(None), slice(None)),
                 code[None])

    @pl.when(k == last)
    def _final():
        # Statically known: which banks are live and their tile indices.
        acc = prod
        if last % gs == 0:  # final tile is itself a group start -> APSQ
            if last > 0:
                for j in range(gs):
                    acc = acc + _dequantize(banks_ref[j], exp(last - gs + j))
        else:  # mid-group: fold the stored tiles since last_start
            for l in range(last_start, last):
                acc = acc + _dequantize(banks_ref[l - last_start], exp(l))
        out_ref[...] = _dequantize(_quantize(acc, exp(last)), exp(last))


def _baseline_kernel(x_ref, w_ref, out_ref, acc_ref, *, n_p: int):
    """INT32-accumulator W8A8 GEMM — the high-precision-PSUM baseline.

    Identical grid/blocking, but the running PSUM is a [bm, bn] INT32 VMEM
    scratch: 4 bytes/element, the paper's beta = 4 working set.
    """
    k = pl.program_id(2)
    prod = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = prod

    @pl.when(k > 0)
    def _acc():
        acc_ref[...] = acc_ref[...] + prod

    @pl.when(k == n_p - 1)
    def _out():
        out_ref[...] = acc_ref[...] if n_p > 1 else prod


def _compiler_params(n_dims: int):
    """dimension_semantics: M/N parallel, K sequential (banks carry state)."""
    sem = ("parallel",) * (n_dims - 1) + ("arbitrary",)
    try:
        return pltpu.CompilerParams(dimension_semantics=sem)
    except AttributeError:  # older jax
        return pltpu.TPUCompilerParams(dimension_semantics=sem)


@functools.partial(
    jax.jit,
    static_argnames=("gs", "block_m", "block_n", "n_p", "interpret"),
)
def apsq_matmul_kernel(
    x_codes: jax.Array,
    w_codes: jax.Array,
    exps: jax.Array,
    *,
    n_p: int,
    gs: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """[M, K] int8 @ [K, N] int8 -> [M, N] int32 (product-scale units).

    ``M % block_m == 0``, ``N % block_n == 0``, ``K % n_p == 0`` — the ops.py
    wrapper pads.  ``exps`` is int32, exponents >= 0: [n_p] (per-tensor
    weight scales; SMEM scalars) or [n_p, N] (per-channel export layout;
    every grid step sees the full n_p rows of its block_n column slice).
    """
    m, kdim = x_codes.shape
    n = w_codes.shape[1]
    assert kdim % n_p == 0 and m % block_m == 0 and n % block_n == 0
    if exps.ndim == 2:
        assert exps.shape == (n_p, n), (exps.shape, n_p, n)
        exp_spec = pl.BlockSpec((n_p, block_n), lambda i, j, k: (0, j))
    else:
        exp_spec = pl.BlockSpec(memory_space=pltpu.SMEM)  # [n_p] scalars
    block_k = kdim // n_p

    grid = (m // block_m, n // block_n, n_p)
    return pl.pallas_call(
        functools.partial(_apsq_kernel, n_p=n_p, gs=gs),
        grid=grid,
        in_specs=[
            exp_spec,
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((gs, block_m, block_n), jnp.int8)],
        compiler_params=_compiler_params(3),
        interpret=interpret,
    )(exps, x_codes, w_codes)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "n_p", "interpret")
)
def baseline_matmul_kernel(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    n_p: int,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """INT32-accumulator W8A8 GEMM with the same grid/blocking as APSQ."""
    m, kdim = x_codes.shape
    n = w_codes.shape[1]
    assert kdim % n_p == 0 and m % block_m == 0 and n % block_n == 0
    block_k = kdim // n_p

    grid = (m // block_m, n // block_n, n_p)
    return pl.pallas_call(
        functools.partial(_baseline_kernel, n_p=n_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_compiler_params(3),
        interpret=interpret,
    )(x_codes, w_codes)


def accumulator_vmem_bytes(block_m: int, block_n: int, gs: int) -> dict:
    """Accumulator working-set per output tile: APSQ banks vs INT32 baseline.

    This is the co-design win on TPU: beta 4 -> gs/4 of the baseline bytes
    (gs=1: 4x smaller; gs=4: parity in VMEM but still 4x fewer bytes per
    HBM spill in split-K schedules, since only one bank is in flight).
    """
    return {
        "apsq_banks": gs * block_m * block_n,          # gs INT8 banks
        "baseline_int32": 4 * block_m * block_n,        # one INT32 accum
    }
