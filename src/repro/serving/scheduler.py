"""Request scheduler for continuous batching over the paged KV cache.

Host-side (pure python/numpy) policy layer under ``PagedServingEngine``:

  * ``PageAllocator`` — free-list over the physical page pool.  Page 0 is
    the reserved null page (``repro.serving.paged_cache.NULL_PAGE``) and
    is never handed out; every other page is either on the free list or
    owned by exactly one slot — ``assert_conserved`` checks that
    invariant and the scheduler tests pin it across admit/grow/evict
    churn.
  * ``Scheduler`` — FIFO admission queue plus slot/page bookkeeping:
    - ``submit`` validates a request can ever fit (progress guarantee:
      its full footprint must fit the pool even when running alone);
    - ``admit_next`` pops the queue head when a slot AND the pages for
      the start of its prompt are available (admission never evicts — it
      just waits).  With ``admit_chunk`` set (the engine passes its
      ``prefill_chunk``), only the FIRST chunk's pages gate admission;
      the rest ``grow`` on demand as prefill chunks land, so a long
      prompt no longer has to reserve its whole footprint up front;
    - ``grow`` allocates the next page of a mid-decode slot, up to
      ``max_pages_per_slot``;
    - ``preempt`` releases a slot mid-decode and requeues its request at
      the *front* (preempt-latest / resume-first policy).  Resume is a
      re-prefill over prompt + generated tokens, which is bit-identical
      to the uninterrupted decode because the paged prefill body is the
      decode body.

The scheduler never touches device state; the engine translates its
page-table rows (``table`` [max_slots, max_pages_per_slot] int32, unused
entries = NULL_PAGE) into the jitted decode's gather indices.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from .paged_cache import NULL_PAGE, page_span


class PageAllocator:
    """LIFO free-list of physical pages; page 0 reserved as the null page."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, 0, -1))
        self._owned: dict[int, list[int]] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    def pages_of(self, slot: int) -> list[int]:
        return list(self._owned.get(slot, []))

    def alloc(self, slot: int, n: int = 1) -> list[int] | None:
        """Hand ``n`` pages to ``slot``; None (no change) if pool is dry."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(slot, []).extend(got)
        return got

    def release(self, slot: int) -> int:
        """Return every page owned by ``slot`` to the free list."""
        pages = self._owned.pop(slot, [])
        self._free.extend(reversed(pages))
        return len(pages)

    def assert_conserved(self) -> None:
        """Free + owned partition pages 1..n-1 exactly (no leak, no dup)."""
        seen = list(self._free)
        for pages in self._owned.values():
            seen.extend(pages)
        if sorted(seen) != list(range(1, self.n_pages)):
            raise AssertionError(
                f"page accounting broken: free={sorted(self._free)} "
                f"owned={ {s: p for s, p in self._owned.items()} }")


@dataclasses.dataclass
class SchedulerStats:
    admitted: int = 0
    preempted: int = 0
    finished: int = 0


class Scheduler:
    """Admission queue + slot/page bookkeeping for continuous batching."""

    def __init__(self, *, max_slots: int, n_pages: int, page_size: int,
                 max_pages_per_slot: int | None = None,
                 admit_chunk: int | None = None):
        self.max_slots = max_slots
        self.page_size = page_size
        self.admit_chunk = admit_chunk
        self.max_pages_per_slot = min(
            n_pages - 1,
            max_pages_per_slot if max_pages_per_slot else n_pages - 1)
        self.alloc = PageAllocator(n_pages)
        self.waiting: deque = deque()
        self.slots: list = [None] * max_slots          # slot -> Request
        self._admit_seq = 0
        self._admitted_at = [0] * max_slots            # eviction ordering
        self.table = np.full((max_slots, self.max_pages_per_slot),
                             NULL_PAGE, np.int32)
        self.stats = SchedulerStats()

    # -- capacity ----------------------------------------------------------

    @property
    def capacity_tokens(self) -> int:
        """Max positions one slot can ever hold (its page budget)."""
        return self.max_pages_per_slot * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    # -- queue -------------------------------------------------------------

    def submit(self, req) -> None:
        """Queue a request; rejects ones that could never run to completion."""
        need = self.pages_for(len(req.tokens) + req.max_new_tokens)
        if need > self.max_pages_per_slot:
            raise ValueError(
                f"request {req.uid}: needs {need} pages "
                f"(prompt {len(req.tokens)} + max_new {req.max_new_tokens} "
                f"tokens) > per-slot budget {self.max_pages_per_slot}")
        self.waiting.append(req)

    def admit_next(self):
        """Admit the queue head if a slot and its starting pages are free.

        Returns (slot, request, resume_tokens) or None.  ``resume_tokens``
        is the full prefill stream — prompt plus any tokens generated
        before a preemption — so resumed requests recompute their cache
        exactly.  Without ``admit_chunk`` the whole prompt's pages gate
        admission; with it only the first prefill chunk's do (later pages
        ``grow`` chunk by chunk).  Admission never evicts: if the pool
        cannot host the start of the prompt right now, the head waits for
        running requests to drain.
        """
        if not self.waiting:
            return None
        try:
            slot = self.slots.index(None)
        except ValueError:
            return None
        req = self.waiting[0]
        resume = np.concatenate(
            [np.asarray(req.tokens, np.int32),
             np.asarray(req.out, np.int32)]) if req.out else np.asarray(
                 req.tokens, np.int32)
        # +1: room for the token the prefill's final logits produce.
        first = len(resume) + 1
        if self.admit_chunk is not None:
            first = min(first, max(self.admit_chunk, 1))
        need = self.pages_for(first)
        pages = self.alloc.alloc(slot, need)
        if pages is None:
            return None
        self.waiting.popleft()
        self.slots[slot] = req
        self._admit_seq += 1
        self._admitted_at[slot] = self._admit_seq
        self.table[slot, :need] = pages
        self.stats.admitted += 1
        return slot, req, resume

    # -- mid-decode --------------------------------------------------------

    def grow_span(self, slot: int, start: int, end: int) -> int:
        """Opportunistically grow pages covering positions [start, end).

        Never evicts: allocation stops at the first page the pool cannot
        supply (pages already granted are kept — they cover the slot's
        next writes anyway).  Returns the number of positions covered
        from ``start``; the engine turns it into the slot's fused-decode
        step budget.  ``start`` must be page-aligned relative to the
        slot's already-guaranteed pages (the engine passes the end of the
        page holding ``pos``)."""
        covered = 0
        for pstart in page_span(start, end, self.page_size):
            if not self.grow(slot, pstart):
                break
            covered = pstart + self.page_size - start
        return max(covered, 0)

    def grow(self, slot: int, pos: int) -> bool:
        """Ensure the page holding position ``pos`` exists for ``slot``.

        True if the slot can write ``pos`` now; False if the pool is dry
        (caller evicts someone and retries).  Raises if ``pos`` is beyond
        the slot's page budget — the engine finishes such requests first.
        """
        idx = pos // self.page_size
        if idx >= self.max_pages_per_slot:
            raise ValueError(f"slot {slot}: pos {pos} beyond page budget")
        if self.table[slot, idx] != NULL_PAGE:
            return True
        got = self.alloc.alloc(slot, 1)
        if got is None:
            return False
        self.table[slot, idx] = got[0]
        return True

    def evict_candidate(self, exclude: int | None = None) -> int | None:
        """Latest-admitted active slot (preempt-latest loses least work)."""
        live = [s for s, r in enumerate(self.slots)
                if r is not None and s != exclude]
        if not live:
            return None
        return max(live, key=lambda s: self._admitted_at[s])

    def preempt(self, slot: int):
        """Release a slot mid-decode; its request requeues at the front."""
        req = self.slots[slot]
        self._clear(slot)
        self.waiting.appendleft(req)
        self.stats.preempted += 1
        return req

    def finish(self, slot: int):
        """Release a completed slot."""
        req = self.slots[slot]
        self._clear(slot)
        self.stats.finished += 1
        return req

    def _clear(self, slot: int) -> None:
        self.alloc.release(slot)
        self.table[slot] = NULL_PAGE
        self.slots[slot] = None

    # -- invariants --------------------------------------------------------

    def assert_invariants(self) -> None:
        """Free-list conservation + slot/table/ownership consistency."""
        self.alloc.assert_conserved()
        for s in range(self.max_slots):
            owned = set(self.alloc.pages_of(s))
            mapped = set(int(p) for p in self.table[s]) - {NULL_PAGE}
            if self.slots[s] is None:
                assert not owned and not mapped, f"slot {s} leaked pages"
            else:
                assert mapped == owned, (
                    f"slot {s}: table {sorted(mapped)} != "
                    f"owned {sorted(owned)}")
