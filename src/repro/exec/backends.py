"""Execution backends for deployed integer GEMMs.

The deployed model (``DeployedQuantState`` params, see ``repro.quant.export``)
describes *what* to compute — INT8 codes, PO2 shift exponents, Algorithm-1
PSUM handling — but not *how*.  This module owns the "how": a small registry
of backends behind one entry point, ``execute_gemm``:

  * ``oracle`` — the pure-jnp integer semantics
    (``kernels/apsq_matmul/ref``).  Runs anywhere, shape-polymorphic,
    differentiable-adjacent; the reference all other backends must match
    bit-for-bit.
  * ``pallas`` — the real ``kernels/apsq_matmul`` Pallas TPU kernel
    (INT8 PSUM banks in VMEM).  On CPU it runs in interpret mode, so the
    same code path is CI-testable; on TPU it is the hardware datapath the
    paper's energy claims (§V) ride on.
  * ``auto``   — ``pallas`` when the default JAX backend is TPU, else
    ``oracle``.  The serving default: decode hits the kernel on hardware
    and stays bit-identical on CPU.

Every projection GEMM in the model zoo dispatches here when its params are
deployed (``models.common.dense`` -> ``core.deployed_dense`` ->
``execute_gemm``), including MoE expert banks and the tied-embedding head,
so QAT fake-quant, the oracle, and the kernel are provably one semantics
on a single code path.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import DeployedQuantState, QuantConfig, qrange


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class ExecBackend:
    """How the integer op families on exported/quantized data are computed.

    Two op families, one registry:

    * ``int_gemm`` consumes INT8 activation codes [M, K], a deployed
      layer's weight codes [K, N] and PSUM shift exponents ([n_p] or
      [n_p, N]; None for plain W8A8) and returns the INT32 result in
      product-scale units.
    * ``kv_attention`` consumes a query [B, Hq, hd] (float), an INT8 KV
      cache ([B, S, Hkv, hd] codes with per-(batch, head) PO2 exponents)
      and per-batch valid lengths, and returns decode attention output
      [B, Hq, hd] — the serving engine's paged-cache read path.
    """

    name = "base"

    def int_gemm(self, x_codes: jax.Array, w_codes: jax.Array,
                 psum_exps: jax.Array | None, *, gs: int) -> jax.Array:
        raise NotImplementedError

    def kv_attention(self, q: jax.Array, k_codes: jax.Array,
                     v_codes: jax.Array, k_exp: jax.Array,
                     v_exp: jax.Array, length: jax.Array, *,
                     block_s: int) -> jax.Array:
        raise NotImplementedError

    def resolve(self) -> "ExecBackend":
        """The concrete backend that will execute (identity for leaves)."""
        return self

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class OracleBackend(ExecBackend):
    """Pure-jnp semantics (``apsq_matmul.ref`` / ``int8_kv_attention.ref``)."""

    name = "oracle"

    def int_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        from repro.kernels.apsq_matmul import ref  # lazy: keep import light
        if psum_exps is None:
            return ref.baseline_matmul_ref(x_codes, w_codes)
        n_p = int(psum_exps.shape[0])
        return ref.apsq_matmul_ref(x_codes, w_codes, psum_exps,
                                   n_p=n_p, gs=gs)

    def kv_attention(self, q, k_codes, v_codes, k_exp, v_exp, length, *,
                     block_s):
        from repro.kernels.int8_kv_attention import int8_kv_attention_ref
        return int8_kv_attention_ref(q, k_codes, v_codes, k_exp, v_exp,
                                     length)


class PallasBackend(ExecBackend):
    """The real Pallas kernels (interpret mode off-TPU, hardware on TPU).

    ``interpret=None`` auto-selects (interpret unless running on TPU);
    pass ``interpret=True`` to force the interpreter (CI determinism).
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None):
        self.interpret = interpret

    def int_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        from repro.kernels.apsq_matmul import (
            apsq_matmul_int8,
            baseline_matmul_int8,
        )
        if psum_exps is None:
            return baseline_matmul_int8(x_codes, w_codes, n_p=1,
                                        interpret=self.interpret)
        return apsq_matmul_int8(x_codes, w_codes, psum_exps, gs=gs,
                                interpret=self.interpret)

    def kv_attention(self, q, k_codes, v_codes, k_exp, v_exp, length, *,
                     block_s):
        from repro.kernels.int8_kv_attention import int8_kv_attention
        return int8_kv_attention(q, k_codes, v_codes, k_exp, v_exp, length,
                                 block_s=block_s, interpret=self.interpret)


class AutoBackend(ExecBackend):
    """``pallas`` on TPU, ``oracle`` elsewhere (resolved at trace time)."""

    name = "auto"

    def resolve(self) -> ExecBackend:
        if jax.default_backend() == "tpu":
            return get_backend("pallas")
        return get_backend("oracle")

    def int_gemm(self, x_codes, w_codes, psum_exps, *, gs):
        return self.resolve().int_gemm(x_codes, w_codes, psum_exps, gs=gs)

    def kv_attention(self, q, k_codes, v_codes, k_exp, v_exp, length, *,
                     block_s):
        return self.resolve().kv_attention(q, k_codes, v_codes, k_exp,
                                           v_exp, length, block_s=block_s)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_backend(name: str, backend: ExecBackend) -> None:
    _REGISTRY[name] = backend


register_backend("oracle", OracleBackend())
register_backend("pallas", PallasBackend())
register_backend("auto", AutoBackend())

DEFAULT_BACKEND = "auto"


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


def get_backend(backend=None) -> ExecBackend:
    """Resolve a backend name / instance / None (-> the ``auto`` default)."""
    if backend is None:
        backend = DEFAULT_BACKEND
    if isinstance(backend, ExecBackend):
        return backend
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise KeyError(f"unknown exec backend {backend!r}; "
                       f"known: {available_backends()}") from None


# ---------------------------------------------------------------------------
# The one entry point the model zoo dispatches through
# ---------------------------------------------------------------------------

def quantize_activations(x2d: jax.Array, ax_exp: jax.Array,
                         a_bits: int = 8) -> jax.Array:
    """Float activations [M, K] -> INT8 codes at the PO2 scale 2^ax_exp."""
    qn, qp = qrange(a_bits, True)
    xf = x2d.astype(jnp.float32)
    return jnp.clip(jnp.round(xf * jnp.exp2(-ax_exp.astype(jnp.float32))),
                    qn, qp).astype(jnp.int8)


def execute_gemm(dq: DeployedQuantState, x: jax.Array, *,
                 backend=None) -> jax.Array:
    """Run one deployed linear: quantize -> integer GEMM -> rescale.

    ``x`` is [..., K] float; the result is [..., *dq.out_dims] in x.dtype.
    The leading dims are flattened to M (decode's [B, 1, C] becomes M=B,
    prefill's [B, T, C] becomes M=B*T) — the backend sees one [M, K] x
    [K, N] integer GEMM, pads to its block constraints (including ragged
    ``K % n_p`` via a zero-contribution remainder PSUM group), and the
    INT32 product-scale output is rescaled by ``2^(ax_exp + aw_exp)``.
    """
    backend = get_backend(backend).resolve()
    spec = dq.spec or QuantConfig.w8a8()
    k = dq.w_codes.shape[-2]
    out_shape = x.shape[:-1] + dq.out_dims
    xc = quantize_activations(x.reshape(-1, k), dq.ax_exp, spec.a_bits)
    gs = 1
    if dq.psum_exps is not None:
        n_p = int(dq.psum_exps.shape[0])
        gs = n_p if spec.psum.mode == "psq" else spec.psum.gs
    y = backend.int_gemm(xc, dq.w_codes, dq.psum_exps, gs=gs)
    scale = jnp.exp2((dq.ax_exp + dq.aw_exp).astype(jnp.float32))
    return (y.astype(jnp.float32) * scale).astype(x.dtype).reshape(out_shape)


def kv_block_size(seq_len: int, requested: int = 512) -> int:
    """Largest divisor of ``seq_len`` that is <= ``requested``.

    The Pallas KV kernel tiles the cache sequence into ``block_s`` chunks
    and requires an exact tiling; the oracle ignores it.  Paged caches
    pass their page size, which divides the gathered sequence by
    construction.
    """
    b = max(1, min(requested, seq_len))
    while seq_len % b:
        b -= 1
    return b


def execute_kv_attention(q: jax.Array, k_codes: jax.Array,
                         v_codes: jax.Array, k_exp: jax.Array,
                         v_exp: jax.Array, length: jax.Array, *,
                         block_s: int | None = None,
                         backend=None) -> jax.Array:
    """Decode attention over an INT8 KV cache through the backend registry.

    q: [B, Hq, hd] float; k_codes/v_codes: [B, S, Hkv, hd] int8 with
    per-(batch, kv-head) PO2 exponents [B, Hkv] int32; ``length`` [B] (or
    scalar) masks the valid cache prefix.  Returns [B, Hq, hd] in q's
    dtype.  This is the second op family beside ``execute_gemm``: the
    ``oracle`` backend runs the shape-polymorphic jnp reference, the
    ``pallas`` backend the flash-decode TPU kernel (interpret off-TPU).
    """
    backend = get_backend(backend).resolve()
    s = int(k_codes.shape[1])
    block_s = kv_block_size(s, block_s if block_s is not None else 512)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32),
                              (k_codes.shape[0],))
    return backend.kv_attention(
        q, k_codes, v_codes, k_exp.astype(jnp.int32),
        v_exp.astype(jnp.int32), length, block_s=block_s)


def backend_parity_check(dq: DeployedQuantState, x: jax.Array, *,
                         backends=("oracle", "pallas"), reps: int = 1,
                         warmup: int = 1):
    """Run one deployed GEMM through several backends, side by side.

    Returns ``(outs, times_us, bit_equal)``: per-backend outputs,
    per-backend wall-clock (jitted, post-warmup, microseconds), and
    whether every output is bit-identical to the first.  Shared by
    ``benchmarks/kernel_bench.py`` and the dry-run's per-cell
    ``backend_parity`` report so parity is measured one way everywhere.
    """
    import time

    import numpy as np

    outs, times = {}, {}
    for be in backends:
        resolved = get_backend(be)
        f = jax.jit(lambda a, _b=resolved: execute_gemm(dq, a, backend=_b))
        for _ in range(warmup):
            jax.block_until_ready(f(x))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = jax.block_until_ready(f(x))
        times[resolved.name] = (time.perf_counter() - t0) / reps * 1e6
        outs[resolved.name] = out
    vals = list(outs.values())
    bit_equal = all(np.array_equal(np.asarray(vals[0]), np.asarray(v))
                    for v in vals[1:])
    return outs, times, bit_equal


def execute_expert_gemm(dq: DeployedQuantState, x: jax.Array, *,
                        backend=None) -> jax.Array:
    """Per-expert deployed GEMM: x [E, C, K] against stacked codes.

    ``dq`` carries a leading expert axis on every data leaf (w_codes
    [E, K, N], ax_exp [E], aw_exp [E, ...], psum_exps [E, n_p, ...] — the
    per-expert exponent banks emitted by ``export_quantized``).  Experts
    are unrolled (E is static and the per-expert shapes are identical, so
    each expert reuses one compiled kernel specialization).
    """
    n_exp = int(dq.w_codes.shape[0])
    outs = []
    for e in range(n_exp):
        dqe = dataclasses.replace(
            dq, w_codes=dq.w_codes[e], ax_exp=dq.ax_exp[e],
            aw_exp=dq.aw_exp[e],
            psum_exps=None if dq.psum_exps is None else dq.psum_exps[e])
        outs.append(execute_gemm(dqe, x[e], backend=backend))
    return jnp.stack(outs)
