"""starcoder2-15b — StarCoder2 15B [arXiv:2402.19173; hf].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152, GQA + RoPE,
gelu MLP, layernorm.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    norm="layernorm",
    mlp="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, norm="layernorm",
        mlp="gelu", dtype="float32")
