"""Fig. 1: energy breakdown of IS/WS/OS for BERT-Base-128 by PSUM width."""
from repro.energy import AcceleratorConfig, bert_base, model_energy

COMPONENTS = ("ifmap", "weight", "psum", "ofmap", "op")


def run(print_fn=print):
    acc = AcceleratorConfig()
    layers = bert_base(128)
    rows = []
    for bits in (8, 16, 32):
        for df in ("IS", "WS", "OS"):
            e = model_energy(layers, acc, df, psum_bits=bits)
            shares = {k: e[k] / e["total"] for k in COMPONENTS}
            rows.append((df, bits, e["total"], shares))
            print_fn(
                f"fig1,{df},psum_int{bits},total_J={e['total']:.3e}," +
                ",".join(f"{k}={shares[k] * 100:.1f}%" for k in COMPONENTS))
    # headline check: PSUM share at INT32 for WS
    ws32 = next(r for r in rows if r[0] == "WS" and r[1] == 32)
    print_fn(f"fig1,headline,WS INT32 psum share,"
             f"{ws32[3]['psum'] * 100:.1f}% (paper: up to 69%)")
    return rows


if __name__ == "__main__":
    run()
