"""QAT integration: capture-based calibration, distillation, gs sweep.

The paper trains APSQ inside W8A8 QAT guided by a full-precision teacher
(§IV-A).  Here:

  * ``calibrate_model``  — a *pure function* over named linears: it runs
    per-unit eager capture passes through the model (``quant_dense``'s
    functional ``tap`` argument collects a ``TapRecord`` per linear — no
    monkey-patching), refines every captured ``QuantState`` with
    ``calibrate_dense`` (activation + running-accumulation PSUM scales),
    and returns a new params tree.  Scan-stacked units
    (``cfg.scan_layers=True``) are sliced per unit so linears that are
    scan tracers in the training forward still get calibrated; MoE expert
    GEMMs are captured at their dispatch buffers.  Each unit is re-applied
    with its calibrated scales before the next unit's capture, so
    downstream statistics see the quantized upstream path.
  * ``distill_loss``     — KL(teacher || student) on logits + CE mix,
    the standard QAT-with-teacher objective.
  * ``quant_variants``   — named per-layer policies for the gs sweep
    (Table I reproduction harness; used by benchmarks/table1_accuracy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import QuantState, calibrate_dense, quant_params_init, \
    tied_head_weight
from repro.models.config import ModelConfig
from repro.models.model import (
    apply_layer,
    apply_unit,
    embed_inputs,
    forward,
    lm_loss,
)
from repro.models.common import apply_norm
from .policy import QuantPolicy


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def _replace_quant_states(tree, calibrated: dict):
    """Swap every ``QuantState`` whose name is in ``calibrated``."""
    if isinstance(tree, QuantState):
        return calibrated.get(tree.name, tree)
    if isinstance(tree, dict):
        return {k: _replace_quant_states(v, calibrated)
                for k, v in tree.items()}
    return tree


def _calibrate_from_taps(taps, sample_tokens: int) -> dict:
    out = {}
    for rec in taps:
        if rec.name in out:  # shared state invoked twice (e.g. MoE experts)
            continue
        xs = rec.x[:sample_tokens]
        out[rec.name] = calibrate_dense(rec.qp, xs, rec.w)
    return out


def _calibrate_block(apply_fn, block_params, sample_tokens: int,
                     passes: int = 2):
    """Capture -> calibrate ``passes`` times over one block.

    One pass is not enough: a linear downstream of another quantized
    linear *within the same block* (MLP wo, MoE experts' wo) sees inputs
    produced with the uncalibrated generic PSUM scales, which can snap
    small activations to zero.  The second pass re-captures with the
    first pass's calibrated scales so downstream statistics are real.
    ``apply_fn(p, tap)`` runs the block and fills the tap.
    """
    new_params = block_params
    for _ in range(passes):
        taps: list = []
        apply_fn(new_params, taps)
        calibrated = _calibrate_from_taps(taps, sample_tokens)
        new_params = _replace_quant_states(new_params, calibrated)
    return new_params


def calibrate_model(params, cfg: ModelConfig, batch: dict,
                    sample_tokens: int = 512):
    """Refine every quantized linear's (ax, ap) from one forward pass.

    Pure: returns a new params tree; ``params`` is not mutated.  Works for
    scan-stacked and unstacked units, MoE, cross-attention, and the
    encoder stack — every ``QuantState`` the forward touches is reachable
    because units are applied one at a time in eager mode with the
    capture tap threaded down to ``quant_dense``.
    """
    tokens = batch.get("tokens")
    new_params = dict(params)

    def calibrate_unit_stack(units, x, *, enc_out, causal, stacked, name):
        """One pass over a (stacked or dict-of-u{i}) unit container."""
        if units is None:
            return units, x
        if stacked:
            n = jax.tree.leaves(units)[0].shape[0]
            get = lambda i: jax.tree.map(lambda a: a[i], units)
        else:
            n = len(units)
            get = lambda i: units[f"u{i}"]
        new_units = []
        for i in range(n):
            new_unit = _calibrate_block(
                lambda pp, tap, _x=x: apply_unit(
                    pp, _x, cfg=cfg, pos=0, enc_out=enc_out, causal=causal,
                    tap=tap),
                get(i), sample_tokens)
            # re-apply with calibrated scales so the next unit's capture
            # sees the quantized upstream activations
            x, _ = apply_unit(new_unit, x, cfg=cfg, pos=0, enc_out=enc_out,
                              causal=causal)
            new_units.append(new_unit)
        if stacked:
            out = jax.tree.map(lambda *xs: jnp.stack(xs), *new_units)
        else:
            out = {f"u{i}": u for i, u in enumerate(new_units)}
        return out, x

    enc_out = None
    if cfg.encdec:
        assert "enc_embeds" in batch, "enc-dec calibration needs enc_embeds"
        xe = jnp.asarray(batch["enc_embeds"]).astype(cfg.jdtype)
        enc = params["encoder"]
        new_enc_units, xe = calibrate_unit_stack(
            enc["units"], xe, enc_out=None, causal=False,
            stacked=True, name="encoder.unit")
        new_params["encoder"] = {**enc, "units": new_enc_units}
        enc_out = apply_norm(enc["final_norm"], xe, cfg.norm)

    x = embed_inputs(params, cfg, tokens, batch.get("embeds"))
    new_units, x = calibrate_unit_stack(
        params["units"], x, enc_out=enc_out, causal=True,
        stacked=cfg.scan_layers, name="unit")
    new_params["units"] = new_units

    if cfg.n_rem:
        new_rem = dict(params["rem"])
        for i in range(cfg.n_rem):
            new_rem[str(i)] = _calibrate_block(
                lambda pp, tap, _x=x, _i=i: apply_layer(
                    pp, _x, cfg=cfg, kind=cfg.block_pattern[_i], pos=0,
                    enc_out=enc_out, tap=tap),
                params["rem"][str(i)], sample_tokens)
            x, _ = apply_layer(new_rem[str(i)], x, cfg=cfg,
                               kind=cfg.block_pattern[i], pos=0,
                               enc_out=enc_out)
        new_params["rem"] = new_rem

    # Tied-embedding head: the logits GEMM (x @ table.T) is a projection
    # like any other — give it a quantizer state (policy name "head") and
    # calibrate it on the final-norm hidden states so export can emit its
    # INT8 codes + shift exponents (ROADMAP: tied-head integer export).
    if cfg.tie_embeddings:
        from .policy import resolve_quant
        resolved = resolve_quant(cfg.policy, "head")
        if resolved is not None:
            w2d = tied_head_weight(params["embed"]["table"])
            xh = apply_norm(params["final_norm"], x, cfg.norm)
            qp0 = params["embed"].get("qp_head")
            if not isinstance(qp0, QuantState):
                qp0 = quant_params_init(w2d, resolved, name="head")
            qp = calibrate_dense(
                qp0, xh.reshape(-1, xh.shape[-1])[:sample_tokens], w2d)
            new_params["embed"] = {**params["embed"], "qp_head": qp}
    return new_params


# ---------------------------------------------------------------------------
# Distillation
# ---------------------------------------------------------------------------

def distill_loss(student_logits: jax.Array, teacher_logits: jax.Array,
                 labels: jax.Array, alpha: float = 0.5,
                 temperature: float = 2.0) -> jax.Array:
    """alpha * KL(teacher || student) * T^2 + (1 - alpha) * CE(labels)."""
    t = temperature
    sl = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    tl = jax.nn.softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    kl = jnp.sum(tl * (jnp.log(jnp.maximum(tl, 1e-20)) - sl), axis=-1)
    ce = lm_loss(student_logits, labels)
    return alpha * jnp.mean(kl) * (t * t) + (1 - alpha) * ce


def make_distill_loss_fn(cfg_student: ModelConfig, cfg_teacher: ModelConfig,
                         teacher_params, alpha: float = 0.5,
                         temperature: float = 2.0):
    """(student_params, batch) -> loss with frozen FP teacher logits."""
    def loss_fn(params, batch):
        s_logits = forward(params, cfg_student, batch["tokens"],
                           embeds=batch.get("embeds"),
                           enc_embeds=batch.get("enc_embeds"))
        t_logits = jax.lax.stop_gradient(
            forward(teacher_params, cfg_teacher, batch["tokens"],
                    embeds=batch.get("embeds"),
                    enc_embeds=batch.get("enc_embeds")))
        return distill_loss(s_logits, t_logits, batch["labels"],
                            alpha, temperature)
    return loss_fn


# ---------------------------------------------------------------------------
# gs sweep harness (Table I)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    gs: int
    mode: str
    final_loss: float
    eval_loss: float


def quant_variants(gs_values=(1, 2, 3, 4), n_p: int = 8) -> dict:
    """Named policies: W8A8 baseline + APSQ at each gs + PSQ.

    Each value is a (uniform) ``QuantPolicy`` consumable by
    ``ModelConfig.with_quant`` / ``configs.get_config(quant=...)``.
    """
    from repro.core import QuantConfig
    out = {"baseline_w8a8": QuantPolicy.uniform(QuantConfig.w8a8())}
    for gs in gs_values:
        out[f"apsq_gs{gs}"] = QuantPolicy.uniform(
            QuantConfig.apsq(gs=gs, n_p=n_p))
    out["psq"] = QuantPolicy.uniform(QuantConfig.psq(n_p=n_p))
    return out


def policy_presets() -> dict:
    """Named *heterogeneous* per-layer policies for roofline/dryrun sweeps.

    These are the co-exploration points the RAE's reconfigurability
    enables (different (gs, n_p) per layer class); ``launch/dryrun.py``
    surfaces them via ``--quant-policy`` so roofline cells can compare
    heterogeneous policies against the uniform presets.
    """
    from repro.core import QuantConfig
    apsq = QuantConfig.apsq
    return {
        # attention projections tight (small gs), FFN loose (bigger gs)
        "mix2_ffn4": QuantPolicy.of(
            ("*.mix.*", apsq(gs=2, n_p=4)),
            ("*.ffn.*", apsq(gs=4, n_p=8)),
            default=QuantConfig.w8a8()),
        # PSUM-quantize only the FFN (attention stays plain W8A8)
        "ffn_only": QuantPolicy.of(
            ("*.ffn.*", apsq(gs=2, n_p=8)),
            default=QuantConfig.w8a8()),
        # aggressive everywhere incl. remainder layers, fine K tiling
        "aggressive": QuantPolicy.of(
            ("rem.*", apsq(gs=1, n_p=16)),
            ("*", apsq(gs=2, n_p=16))),
        # encoder quantized harder than decoder (encdec archs)
        "enc_heavy": QuantPolicy.of(
            ("encoder.*", apsq(gs=1, n_p=8)),
            ("*", apsq(gs=4, n_p=4))),
    }
