"""seamless-m4t-large-v2 — SeamlessM4T v2 large [arXiv:2308.11596; hf].

Enc-dec transformer BACKBONE only: 24 encoder + 24 decoder layers,
d_model=1024, 16 heads (GQA kv=16), d_ff=8192, vocab=256206.
Audio frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings [B, S, d_model] consumed directly by the encoder.
Adaptations (DESIGN.md): RoPE replaces learned positions; layernorm.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    norm="layernorm",
    mlp="gelu",
    encdec=True,
    n_enc_layers=24,
    frontend="audio",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="encdec", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, norm="layernorm",
        mlp="gelu", encdec=True, n_enc_layers=2, frontend="audio",
        dtype="float32")
