"""AdamW with gradient clipping, schedules, and a weight-decay mask.

Plain-pytree implementation (no optax dependency): state = {m, v, step}.
Quantizer scales (LSQ alphas / PO2 log-alphas) and norm params are excluded
from weight decay via a path-based mask, matching LSQ practice.

``adafactor_like=True`` switches the second moment to factored row/col
statistics for 2D+ params (memory: O(m+n) instead of O(mn)) — the
large-model option used by the qwen3-235b config.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


NO_DECAY_KEYS = ("scale", "bias", "ln", "norm", "ax", "aw", "ap", "mu",
                 "u", "w0", "lam", "gate_a_b", "gate_x_b")


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    adafactor_like: bool = False


def lr_schedule(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def decay_mask(params) -> dict:
    """True where weight decay applies (2D+ weights, not scales/norms)."""
    def mask_leaf(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(str(n) in NO_DECAY_KEYS for n in names):
            return False
        return getattr(leaf, "ndim", 0) >= 2

    return jax.tree_util.tree_map_with_path(mask_leaf, params)


def _factored(shape: tuple) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def init_opt_state(params, cfg: OptimConfig) -> dict:
    def zeros_like_f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    m = jax.tree.map(zeros_like_f32, params)
    if cfg.adafactor_like:
        def v_init(p):
            if _factored(p.shape):
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32)}
            return {"full": jnp.zeros(p.shape, jnp.float32)}
        v = jax.tree.map(v_init, params)
    else:
        v = jax.tree.map(zeros_like_f32, params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _second_moment_value(v, _unused=None):
    if "full" in v:
        return v["full"]
    row, col = v["row"], v["col"]
    denom = jnp.maximum(jnp.mean(row, axis=-1, keepdims=True), 1e-30)
    return row[..., None] * col[..., None, :] / denom[..., None]


def apply_updates(params, grads, state, cfg: OptimConfig,
                  mask=None) -> tuple:
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    if mask is None:
        mask = decay_mask(params)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         state["m"], grads)
    if cfg.adafactor_like:
        is_v = lambda x: isinstance(x, dict) and ("full" in x or "row" in x)

        def v_upd(v, g):
            g2 = jnp.square(g)
            if "full" in v:
                return {"full": b2 * v["full"] + (1 - b2) * g2}
            return {"row": b2 * v["row"] + (1 - b2) * jnp.mean(g2, axis=-1),
                    "col": b2 * v["col"] + (1 - b2) * jnp.mean(g2, axis=-2)}

        new_v = jax.tree.map(v_upd, state["v"], grads, is_leaf=is_v)
        v_hat = jax.tree.map(lambda v: _second_moment_value(v, None) / bc2,
                             new_v, is_leaf=is_v)
    else:
        new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                             state["v"], grads)
        v_hat = jax.tree.map(lambda v: v / bc2, new_v)

    def upd(p, m, vh, use_wd):
        u = (m / bc1) / (jnp.sqrt(vh) + cfg.eps)
        if use_wd:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, v_hat, mask)
    stats = {"lr": lr, "grad_norm": gnorm, "step": step}
    return new_params, {"m": new_m, "v": new_v, "step": step}, stats
