"""APSQ matmul Pallas kernel: W8A8 GEMM with INT8 PSUM banks (RAE on TPU)."""
from .kernel import (
    accumulator_vmem_bytes,
    apsq_expert_matmul_kernel,
    apsq_matmul_kernel,
    apsq_matmul_m1_kernel,
    baseline_expert_matmul_kernel,
    baseline_matmul_kernel,
)
from .ops import (
    apsq_expert_matmul_int8,
    apsq_matmul_f32,
    apsq_matmul_int8,
    baseline_expert_matmul_int8,
    baseline_matmul_int8,
    calibrate_exps,
    quantize_operands,
)
from .ref import (
    apsq_matmul_ref,
    baseline_matmul_ref,
    choose_exps,
    dequantize_psum,
    pad_ragged_k,
    psum_tiles,
    quantize_psum,
    rshift_round,
)

__all__ = [
    "accumulator_vmem_bytes", "apsq_expert_matmul_kernel",
    "apsq_matmul_kernel", "apsq_matmul_m1_kernel",
    "baseline_expert_matmul_kernel", "baseline_matmul_kernel",
    "apsq_expert_matmul_int8", "apsq_matmul_f32", "apsq_matmul_int8",
    "baseline_expert_matmul_int8", "baseline_matmul_int8",
    "calibrate_exps", "quantize_operands", "apsq_matmul_ref",
    "baseline_matmul_ref", "choose_exps", "dequantize_psum", "pad_ragged_k",
    "psum_tiles", "quantize_psum", "rshift_round",
]
