"""Core APSQ library: quantizers, Algorithm-1 accumulation, quantized linears."""
from .quantizers import (
    QuantSpec,
    floor_ste,
    grad_scale,
    init_alpha_from,
    init_log2_alpha_from,
    lsq_gradient_scale,
    lsq_quantize,
    po2_quantize,
    po2_quantize_codes,
    po2_scale,
    qrange,
    round_half_up_ste,
    round_ste,
)
from .apsq import (
    apsq_accumulate,
    apsq_accumulate_reference,
    apsq_matmul,
    psq_accumulate,
)
from .layers import (
    DeployedQuantState,
    PsumQuantConfig,
    QuantConfig,
    QuantState,
    TapRecord,
    calibrate_dense,
    deployed_dense,
    effective_n_p,
    quant_dense,
    quant_params_init,
    tied_head_weight,
)

__all__ = [
    "QuantSpec", "floor_ste", "grad_scale", "init_alpha_from",
    "init_log2_alpha_from", "lsq_gradient_scale", "lsq_quantize",
    "po2_quantize", "po2_quantize_codes", "po2_scale", "qrange",
    "round_half_up_ste", "round_ste",
    "apsq_accumulate", "apsq_accumulate_reference", "apsq_matmul",
    "psq_accumulate", "DeployedQuantState", "PsumQuantConfig", "QuantConfig",
    "QuantState", "TapRecord", "calibrate_dense", "deployed_dense",
    "effective_n_p", "quant_dense", "quant_params_init", "tied_head_weight",
]
