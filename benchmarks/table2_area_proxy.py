"""Table II proxy: RAE overhead relative to the baseline accelerator.

Synopsys synthesis is out of scope; the honest proxy is resource
accounting of what the RAE adds per the paper's Fig. 2: 4 INT8 PSUM SRAM
banks + shifter quant/dequant + a 2-stage adder pipeline + control,
relative to the MAC array + buffers of the baseline accelerator.  We count
storage bits and arithmetic-op bits — the dominant area contributors at a
fixed technology node — and report the overhead ratio next to the paper's
synthesized 3.21%.

On TPU the analogous cost is the kernel's VMEM scratch: APSQ banks vs the
INT32 accumulator (also reported).
"""
from repro.energy import AcceleratorConfig
from repro.kernels.apsq_matmul import accumulator_vmem_bytes


def run(print_fn=print):
    acc = AcceleratorConfig()
    # Baseline accelerator storage (bits): I/O/W buffers + MAC array regs.
    buf_bits = (acc.B_i + acc.B_o + acc.B_w) * 8
    macs = acc.P_o * acc.P_ci * acc.P_co
    # area proxy per INT8 MAC ~ mult(8x8) + 32b add ~ 500 gate-equivalents;
    # SRAM bit ~ 1 GE-equivalent at the same node (order-of-magnitude).
    mac_ge = macs * 500
    sram_ge = buf_bits * 1
    base_ge = mac_ge + sram_ge

    # RAE: 4 banks x P_o*P_co INT8 entries, 2 shifters (32b barrel ~ 300 GE)
    # per lane, adder pipeline (4 x 32b adds ~ 120 GE) per lane, control.
    lanes = acc.P_o * acc.P_co
    rae_banks_bits = 4 * lanes * 8
    rae_ge = rae_banks_bits * 1 + lanes * (2 * 300 + 4 * 120) + 2000
    ratio = rae_ge / base_ge
    print_fn(f"table2,baseline_GE={base_ge:.3e},rae_GE={rae_ge:.3e},"
             f"overhead={ratio * 100:.2f}% (paper synthesized: 3.21%)")

    v = accumulator_vmem_bytes(128, 128, gs=1)
    print_fn(f"table2,tpu_analogue,apsq_vmem={v['apsq_banks']}B,"
             f"int32_acc={v['baseline_int32']}B,"
             f"ratio={v['apsq_banks'] / v['baseline_int32']:.2f}")
    return ratio


if __name__ == "__main__":
    run()
