"""Distribution utilities: logical-axis sharding rules + gradient compression.

``sharding`` maps the logical axis names used by every ``*_specs`` tree in
``repro.models`` onto concrete mesh axes (with divisibility fallbacks and
no-axis-reuse), and ``compress`` implements the INT8 cross-pod gradient
path the trainer uses over the DCN ("pod") axis.
"""
from .sharding import (
    DEFAULT_RULES,
    batch_spec,
    optimizer_spec,
    shard_map,
    spec_for,
    tree_specs,
)
from .compress import (
    compress_tree_psum,
    dequantize_grad,
    quantize_grad,
)

__all__ = [
    "DEFAULT_RULES", "batch_spec", "optimizer_spec", "shard_map",
    "spec_for", "tree_specs", "compress_tree_psum", "dequantize_grad",
    "quantize_grad",
]
