"""Layer walks (GEMM inventories) for the paper's evaluation models.

Every workload is a list of ``LayerShape`` — the exact GEMMs an IS/WS
accelerator executes, with ``repeat`` folding identical layers.  Attention
score GEMMs (QK^T, PV) are included as per-head layers with C_i = head_dim;
their PSUM working set is small (n_p = head_dim / P_ci tiles) which is why
the paper's energy story is dominated by projection / FFN GEMMs.

Also provides ``arch_layers(cfg, seq_len)`` mapping ANY repro ModelConfig
(the 10 assigned architectures) onto the analytical model — the paper's
framework extended to the assignment's model zoo (used by the energy
benchmarks beyond the paper's own four models).
"""
from __future__ import annotations

from .model import LayerShape


def bert_base(seq: int = 128) -> list:
    """BERT-Base: 12 L, d=768, ffn=3072, 12 heads (paper Fig. 1 / Table I)."""
    d, ff, L, H = 768, 3072, 12, 12
    hd = d // H
    return [
        LayerShape("qkv", seq, d, 3 * d, repeat=L),
        LayerShape("attn_scores", seq, hd, seq, repeat=L * H),
        LayerShape("attn_values", seq, seq, hd, repeat=L * H),
        LayerShape("attn_out", seq, d, d, repeat=L),
        LayerShape("ffn_in", seq, d, ff, repeat=L),
        LayerShape("ffn_out", seq, ff, d, repeat=L),
    ]


def segformer_b0(res: int = 512) -> list:
    """Segformer-B0 @ res^2: 4 stages, dims [32,64,160,256], depths
    [2,2,2,2], efficient attn reduction [8,4,2,1], MLP ratio [8,8,4,4]."""
    dims = (32, 64, 160, 256)
    depths = (2, 2, 2, 2)
    sr = (8, 4, 2, 1)          # spatial reduction of K/V
    mlp = (8, 8, 4, 4)
    heads = (1, 2, 5, 8)
    layers: list = []
    tok = (res // 4) ** 2      # stage-1 tokens (stride-4 patch embed)
    for s, (d, dep, r, m, h) in enumerate(zip(dims, depths, sr, mlp, heads)):
        t = tok // (4 ** s)
        tk = t // (r * r)      # reduced kv tokens
        hd = d // h
        layers += [
            LayerShape(f"s{s}_q", t, d, d, repeat=dep),
            LayerShape(f"s{s}_kv", tk, d, 2 * d, repeat=dep),
            LayerShape(f"s{s}_scores", t, hd, tk, repeat=dep * h),
            LayerShape(f"s{s}_values", t, tk, hd, repeat=dep * h),
            LayerShape(f"s{s}_proj", t, d, d, repeat=dep),
            LayerShape(f"s{s}_mlp_in", t, d, m * d, repeat=dep),
            LayerShape(f"s{s}_mlp_out", t, m * d, d, repeat=dep),
        ]
    return layers


def efficientvit_b1(res: int = 512) -> list:
    """EfficientViT-B1 @ res^2: widths [16,32,64,128,256], ReLU linear
    attention in stages 3-5, MBConv expand 4 (conv as 1x1 GEMM view)."""
    widths = (16, 32, 64, 128, 256)
    depths = (1, 2, 3, 3, 4)
    layers: list = []
    for s, (w, dep) in enumerate(zip(widths, depths)):
        t = (res // (2 ** (s + 1))) ** 2
        # MBConv: expand 1x1, project 1x1 (depthwise omitted: not a GEMM)
        layers += [
            LayerShape(f"s{s}_mb_in", t, w, 4 * w, repeat=dep),
            LayerShape(f"s{s}_mb_out", t, 4 * w, w, repeat=dep),
        ]
        if s >= 2:  # EfficientViT module: linear attention qkv + proj
            layers += [
                LayerShape(f"s{s}_qkv", t, w, 3 * w, repeat=dep),
                # ReLU linear attention: (k^T v) then q (k^T v) — two
                # GEMMs with C_i = t and C_i = head_dim respectively;
                # aggregate heads (dim 16) into one shape.
                LayerShape(f"s{s}_ktv", 16, t, w, repeat=dep),
                LayerShape(f"s{s}_qktv", t, 16, w, repeat=dep),
                LayerShape(f"s{s}_proj", t, w, w, repeat=dep),
            ]
    return layers


def llama2_7b(seq: int = 4096, stage: str = "prefill") -> list:
    """LLaMA2-7B: 32 L, d=4096, ffn=11008, 32 heads.

    stage='prefill': the full-sequence pass (T = seq).
    stage='decode' : one token (T = 1) attending to a seq-long KV cache —
    per generated token; the paper's Table IV combines both at seq 4096.
    """
    d, ff, L, H = 4096, 11008, 32, 32
    hd = d // H
    if stage == "prefill":
        T, Tkv = seq, seq
    else:
        T, Tkv = 1, seq
    return [
        LayerShape("qkv", T, d, 3 * d, repeat=L),
        LayerShape("attn_scores", T, hd, Tkv, repeat=L * H),
        LayerShape("attn_values", T, Tkv, hd, repeat=L * H),
        LayerShape("attn_out", T, d, d, repeat=L),
        LayerShape("ffn_gate", T, d, ff, repeat=L),
        LayerShape("ffn_up", T, d, ff, repeat=L),
        LayerShape("ffn_down", T, ff, d, repeat=L),
    ]


def llama2_7b_combined(seq: int = 4096) -> list:
    """Table IV workload: the paper simulates the decoding stage by keeping
    the total MAC count unchanged (T = seq) and moving the parallelism to
    P_o=1, P_ci=P_co=32 (§IV-D) — i.e. the full-sequence layer walk run
    under ``AcceleratorConfig.llm_decode()``.  'Considering both prefilling
    and decoding stages' is that same walk: prefill and MAC-preserving
    decode share the shapes, only the accelerator config differs."""
    return llama2_7b(seq, "prefill")


def llama2_7b_autoregressive(seq: int = 4096) -> list:
    """Physical per-token decode walk (T=1, repeated seq times) — the
    weight-streaming-bound reality check reported next to Table IV."""
    dec = llama2_7b(seq, "decode")
    return [LayerShape(l.name + "_dec", l.tokens, l.c_i, l.c_o,
                       repeat=l.repeat * seq) for l in dec]


# ---------------------------------------------------------------------------
# Assigned-architecture walks (beyond the paper's own four models)
# ---------------------------------------------------------------------------

def arch_layers(cfg, seq_len: int, stage: str = "prefill") -> list:
    """Map a repro ModelConfig onto the analytical accelerator model.

    Walks the same GEMMs the JAX model executes: per-block projections,
    FFN / MoE-active-expert GEMMs, attention score GEMMs for attn blocks.
    """
    T = 1 if stage == "decode" else seq_len
    Tkv = seq_len
    hd = cfg.hd
    d = cfg.d_model
    layers: list = []
    pat = cfg.block_pattern
    n_units = cfg.n_layers // len(pat)
    counts = {k: sum(1 for kk in pat if kk == k) * n_units for k in set(pat)}
    for i in range(cfg.n_layers % len(pat)):
        counts[pat[i]] = counts.get(pat[i], 0) + 1

    n_attn = counts.get("attn", 0) + counts.get("local", 0)
    if n_attn:
        q_dim = cfg.n_heads * hd
        kv_dim = cfg.n_kv_heads * hd
        win = min(cfg.local_window, Tkv)
        layers += [
            LayerShape("wq", T, d, q_dim, repeat=n_attn),
            LayerShape("wk", T, d, kv_dim, repeat=n_attn),
            LayerShape("wv", T, d, kv_dim, repeat=n_attn),
            LayerShape("wo", T, q_dim, d, repeat=n_attn),
        ]
        for kind, cnt in (("attn", counts.get("attn", 0)),
                          ("local", counts.get("local", 0))):
            if not cnt:
                continue
            kv_t = Tkv if kind == "attn" else win
            layers += [
                LayerShape(f"{kind}_scores", T, hd, kv_t,
                           repeat=cnt * cfg.n_heads),
                LayerShape(f"{kind}_values", T, kv_t, hd,
                           repeat=cnt * cfg.n_heads),
            ]
    if counts.get("rwkv", 0):
        n = counts["rwkv"]
        a = cfg.n_heads * hd
        layers += [LayerShape(f"rwkv_{nm}", T, d, a, repeat=n)
                   for nm in ("wr", "wk", "wv", "wg")]
        layers += [LayerShape("rwkv_wo", T, a, d, repeat=n)]
    if counts.get("rglru", 0):
        n = counts["rglru"]
        r = cfg.d_rnn
        layers += [
            LayerShape("rglru_wx", T, d, r, repeat=n),
            LayerShape("rglru_wy", T, d, r, repeat=n),
            LayerShape("rglru_gates", T, r, 2 * r, repeat=n),
            LayerShape("rglru_wo", T, r, d, repeat=n),
        ]

    L = cfg.n_layers
    if cfg.mlp == "moe":
        # top_k active experts per token; expert GEMMs at C_i = d / d_ff.
        k = cfg.top_k
        layers += [
            LayerShape("moe_router", T, d, cfg.n_experts, repeat=L),
            LayerShape("moe_wi", T, d, cfg.d_ff, repeat=L * k),
            LayerShape("moe_wg", T, d, cfg.d_ff, repeat=L * k),
            LayerShape("moe_wo", T, cfg.d_ff, d, repeat=L * k),
        ]
    elif cfg.mlp == "rwkv_cm":
        layers += [
            LayerShape("cm_wr", T, d, d, repeat=L),
            LayerShape("cm_wk", T, d, cfg.d_ff, repeat=L),
            LayerShape("cm_wv", T, cfg.d_ff, d, repeat=L),
        ]
    elif cfg.mlp == "swiglu":
        layers += [
            LayerShape("ffn_gate", T, d, cfg.d_ff, repeat=L),
            LayerShape("ffn_up", T, d, cfg.d_ff, repeat=L),
            LayerShape("ffn_down", T, cfg.d_ff, d, repeat=L),
        ]
    else:  # gelu
        layers += [
            LayerShape("ffn_in", T, d, cfg.d_ff, repeat=L),
            LayerShape("ffn_out", T, cfg.d_ff, d, repeat=L),
        ]
    if cfg.encdec and cfg.n_enc_layers:
        enc = [LayerShape("enc_" + l.name, Tkv, l.c_i, l.c_o,
                          repeat=l.repeat * cfg.n_enc_layers // max(L, 1))
               for l in layers if not l.name.startswith(("moe", "cm"))]
        layers += enc
    return layers
