"""RWKV6 "Finch" — attention-free time mixing with data-dependent decay.

Faithful structure (arXiv:2404.05892): token-shift with data-dependent
lerp (ddlerp via a small LoRA), per-channel data-dependent decay
``w_t = exp(-exp(...))``, bonus ``u``, per-head WKV state recurrence

    y_t = r_t . (S_{t-1} + (u * k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

and a squared-ReLU channel mix.  Two WKV implementations:

  * ``scan``    — one lax.scan step per token (reference; O(1) memory).
  * ``chunked`` — chunk-parallel form: the sequence is split into chunks of
    C tokens; intra-chunk interactions use a [C, C] decay-weighted score
    matmul (MXU-friendly), inter-chunk state is carried by a scan over
    chunks.  All exponents are differences of cumulative log-decays within
    one chunk, hence <= 0 — numerically safe (underflow -> 0).  This is the
    §Perf hillclimb path: T/C scan steps instead of T.

All projections go through ``dense`` (=> quantizable / APSQ-able); the WKV
state itself is fp32 internal and is NOT a GEMM PSUM, so APSQ does not
apply to it (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import QuantConfig
from .common import Params, dense, init_linear, linear_specs

LORA_R = 64        # ddlerp LoRA rank
DECAY_LORA_R = 64  # decay LoRA rank


def init_rwkv_time_mix(key, d_model: int, n_heads: int, head_dim: int, dtype,
                       quant=None, name: str = "") -> Params:
    ks = jax.random.split(key, 12)
    d_attn = n_heads * head_dim
    return {
        # ddlerp: 5 static mixes (r, w, k, v, g) + shared LoRA
        "mu": jnp.zeros((5, d_model), dtype) + 0.5,
        "mix_w1": init_linear(ks[0], (d_model, 5 * LORA_R), dtype),
        "mix_w2": (jax.random.normal(ks[1], (5, LORA_R, d_model), jnp.float32)
                   * 0.01).astype(dtype),
        # projections
        "wr": init_linear(ks[2], (d_model, d_attn), dtype, quant=quant,
                          name=f"{name}.wr"),
        "wk": init_linear(ks[3], (d_model, d_attn), dtype, quant=quant,
                          name=f"{name}.wk"),
        "wv": init_linear(ks[4], (d_model, d_attn), dtype, quant=quant,
                          name=f"{name}.wv"),
        "wg": init_linear(ks[5], (d_model, d_attn), dtype, quant=quant,
                          name=f"{name}.wg"),
        "wo": init_linear(ks[6], (d_attn, d_model), dtype, quant=quant,
                          name=f"{name}.wo"),
        # data-dependent decay
        "w0": jnp.zeros((d_attn,), dtype) - 6.0,  # ~slow decay at init
        "decay_w1": init_linear(ks[7], (d_model, DECAY_LORA_R), dtype),
        "decay_w2": (jax.random.normal(ks[8], (DECAY_LORA_R, d_attn),
                                       jnp.float32) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[9], (n_heads, head_dim), jnp.float32)
              * 0.1).astype(dtype),
        # per-head group norm on the WKV output
        "ln_out": {"scale": jnp.ones((d_attn,), dtype),
                   "bias": jnp.zeros((d_attn,), dtype)},
    }


def rwkv_time_mix_specs(quant=None, name: str = "") -> Params:
    return {
        "mu": (None, "embed"),
        "mix_w1": linear_specs(("embed", None)),
        "mix_w2": (None, None, "embed"),
        "wr": linear_specs(("embed", "qheads"), quant, f"{name}.wr"),
        "wk": linear_specs(("embed", "qheads"), quant, f"{name}.wk"),
        "wv": linear_specs(("embed", "qheads"), quant, f"{name}.wv"),
        "wg": linear_specs(("embed", "qheads"), quant, f"{name}.wg"),
        "wo": linear_specs(("qheads", "embed"), quant, f"{name}.wo"),
        "w0": ("qheads",),
        "decay_w1": linear_specs(("embed", None)),
        "decay_w2": (None, "qheads"),
        "u": ("heads", None),
        "ln_out": {"scale": ("qheads",), "bias": ("qheads",)},
    }


def init_rwkv_channel_mix(key, d_model: int, d_ff: int, dtype,
                          quant=None, name: str = "") -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu": jnp.zeros((2, d_model), dtype) + 0.5,  # (r, k) mixes
        # wr is the sigmoid gate and is applied unquantized below
        "wr": init_linear(k1, (d_model, d_model), dtype),
        "wk": init_linear(k2, (d_model, d_ff), dtype, quant=quant,
                          name=f"{name}.wk"),
        "wv": init_linear(k3, (d_ff, d_model), dtype, quant=quant,
                          name=f"{name}.wv"),
    }


def rwkv_channel_mix_specs(quant=None, name: str = "") -> Params:
    return {
        "mu": (None, "embed"),
        "wr": linear_specs(("embed", "embed_out")),
        "wk": linear_specs(("embed", "ff"), quant, f"{name}.wk"),
        "wv": linear_specs(("ff", "embed"), quant, f"{name}.wv"),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None):
    """xx_t = x_{t-1} (zeros / carried state at t=0).  x: [B, S, d]."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p: Params, x, xx):
    """Data-dependent lerp producing the 5 mixed inputs (r, w, k, v, g)."""
    sx = (xx - x).astype(x.dtype)
    base = x + sx * p["mu"][:, None, None, :]  # [5, B, S, d] via broadcast
    b = jnp.tanh(dense(p["mix_w1"], x, None))  # [B, S, 5R]
    b = b.reshape(b.shape[:-1] + (5, LORA_R))
    adj = jnp.einsum("bsfr,frd->fbsd", b, p["mix_w2"].astype(x.dtype))
    return base + sx[None] * adj  # [5, B, S, d]


def _decay(p: Params, xw: jax.Array) -> jax.Array:
    """log(w_t) = -exp(w0 + lora(xw)) in fp32; w = exp(log_w) in (0, 1)."""
    lo = dense(p["decay_w1"], xw, None)
    lo = jnp.tanh(lo) @ p["decay_w2"].astype(xw.dtype)
    return -jnp.exp((p["w0"].astype(jnp.float32) + lo.astype(jnp.float32)))


def _wkv_scan(r, k, v, log_w, u, state):
    """Reference WKV: scan over time.  r/k/v: [B, S, H, hd] fp32;
    log_w: [B, S, H, hd]; u: [H, hd]; state: [B, H, hd, hd]."""
    def step(s, xs):
        rt, kt, vt, lwt = xs  # [B, H, hd]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = jnp.exp(lwt)[..., None] * s + kv
        return s, yt

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, log_w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state  # [B, S, H, hd], final state


def _wkv_chunked(r, k, v, log_w, u, state, chunk: int = 32,
                 compute_dtype=jnp.float32):
    """Chunk-parallel WKV (GLA-style).  Exponents are in-chunk cumulative
    log-decay differences (<= 0), so everything stays in fp32 safely.

    ``compute_dtype``: dtype of the intra-chunk matmul *operands* (state,
    cumulative decays and accumulation stay fp32).  bf16 halves the
    per-chunk tensor traffic (§Perf iteration 5) at ~1e-2 relative error.
    """
    B, S, H, hd = r.shape
    C = min(chunk, S)
    n = -(-S // C)
    pad = n * C - S
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, log_w = z(r), z(k), z(v), z(log_w)

    rc = r.reshape(B, n, C, H, hd)
    kc = k.reshape(B, n, C, H, hd)
    vc = v.reshape(B, n, C, H, hd)
    lw = log_w.reshape(B, n, C, H, hd)
    cd = compute_dtype
    f32 = jnp.float32

    def chunk_step(s, xs):
        rt, kt, vt, lwt = xs  # [B, C, H, hd]
        # L_t = sum_{i<=t} log w_i  (cumulative within chunk, <= 0)
        L = jnp.cumsum(lwt, axis=1)
        L_prev = L - lwt  # L_{t-1} with L_{-1} = 0
        L_end = L[:, -1:]
        # Inter-chunk: q side sees decay from chunk start to t-1.
        r_in = (rt * jnp.exp(L_prev)).astype(cd)
        y_inter = jnp.einsum("bchk,bhkv->bchv", r_in, s.astype(cd),
                             preferred_element_type=f32)
        # Intra-chunk (strictly causal): decay(s+1 .. t-1) = L_{t-1} - L_s,
        # factored as exp(L_prev_t) * exp(-L_s).  |L| <= chunk * |log_w|_max
        # stays < 80 given the clamp in rwkv_time_mix, so fp32 is safe.
        k_out = (kt * jnp.exp(-L)).astype(cd)  # k_s * exp(-L_s)
        scores = jnp.einsum("bchk,bdhk->bhcd", r_in, k_out,
                            preferred_element_type=f32)
        idx = jnp.arange(C)
        causal = idx[:, None] > idx[None, :]
        scores = jnp.where(causal[None, None], scores, 0.0)
        y_intra = jnp.einsum("bhcd,bdhv->bchv", scores.astype(cd),
                             vt.astype(cd), preferred_element_type=f32)
        # Bonus (current token): (r_t . (u * k_t)) v_t
        bonus = jnp.einsum("bchk,bchk->bch", rt, u[None, None] * kt)
        y_bonus = bonus[..., None] * vt
        # State to next chunk: S' = D(L_end) S + sum_s D(L_end - L_s) k_s v_s
        k_fold = (kt * jnp.exp(L_end - L)).astype(cd)
        s_new = (jnp.exp(L_end[:, 0])[..., None] * s
                 + jnp.einsum("bchk,bchv->bhkv", k_fold, vt.astype(cd),
                              preferred_element_type=f32))
        return s_new, y_inter + y_intra + y_bonus

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, lw))
    # Remat per chunk: without this the backward saves every intra-chunk
    # intermediate (~15 tensors/trip); with it only the state carry is
    # saved and the chunk body recomputes — ~10x less residual traffic
    # for ~1x extra (cheap) chunk flops (§Perf iteration 4).
    state, ys = jax.lax.scan(jax.checkpoint(chunk_step), state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, n * C, H, hd)[:, :S]
    return y, state


def rwkv_time_mix(p: Params, x: jax.Array, *, n_heads: int, head_dim: int,
                  quant=None, impl: str = "scan",
                  state: Params | None = None, wkv_chunk: int = 32,
                  mesh=None, tap: list | None = None, backend=None):
    """RWKV6 time mixing.  state (decode / carry) = {"shift": [B, 1, d],
    "wkv": [B, H, hd, hd]}; pass None for fresh (training) state."""
    from .common import act_spec, act_spec_seq, shard_hint
    B, S, d = x.shape
    H, hd = n_heads, head_dim
    prev = state["shift"] if state is not None else None
    # Sequence parallelism for the ddlerp region: the [5, B, S, d] mixed
    # streams and their gradients are elementwise — sharding S over
    # "model" cuts their (otherwise TP-replicated) traffic 16x (§Perf).
    sspec = act_spec_seq(mesh, B, S)
    xx = _token_shift(x, prev)
    xx = shard_hint(xx, sspec)
    mixed = _ddlerp(p, x, xx)  # [5, B, S, d]
    if sspec is not None:
        mixed = shard_hint(mixed, jax.sharding.NamedSharding(
            sspec.mesh, jax.sharding.PartitionSpec(None, *sspec.spec)))
    xr, xw, xk, xv, xg = mixed

    hspec = act_spec(mesh, B, heads=H)
    r = shard_hint(dense(p["wr"], xr, quant, tap=tap,
                         backend=backend).reshape(B, S, H, hd),
                   hspec).astype(jnp.float32)
    k = shard_hint(dense(p["wk"], xk, quant, tap=tap,
                         backend=backend).reshape(B, S, H, hd),
                   hspec).astype(jnp.float32)
    v = shard_hint(dense(p["wv"], xv, quant, tap=tap,
                         backend=backend).reshape(B, S, H, hd),
                   hspec).astype(jnp.float32)
    g = dense(p["wg"], xg, quant, tap=tap, backend=backend)
    log_w = _decay(p, xw).reshape(B, S, H, hd)
    # Clamp so |cumsum(log_w)| <= wkv_chunk * 2 < 80: the chunked form's
    # exp(+/-L) factors then never leave fp32 range.  (Decay floor of
    # e^-2 per step; faster-than-that decay is indistinguishable after a
    # handful of tokens.)
    log_w = jnp.clip(log_w, -2.0, -1e-4)

    s0 = (state["wkv"] if state is not None
          else jnp.zeros((B, H, hd, hd), jnp.float32))
    u = p["u"].astype(jnp.float32)
    if impl == "chunked" and S > 1:
        # compute_dtype=bf16 was measured in §Perf iteration 5 and
        # REFUTED on the bytes-accessed metric (convert boundary traffic
        # outweighs the halved operand bytes on this fusion layout);
        # keeping fp32 operands.
        y, s_new = _wkv_chunked(r, k, v, log_w, u, s0, chunk=wkv_chunk)
    else:
        y, s_new = _wkv_scan(r, k, v, log_w, u, s0)

    # per-head group norm (sequence-parallel: elementwise region)
    yf = y.reshape(B, S, H, hd)
    yf = shard_hint(yf, act_spec_seq(mesh, B, S, n_trailing=2))
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 1e-5)
    yf = yf.reshape(B, S, H * hd)
    yf = yf * p["ln_out"]["scale"].astype(jnp.float32) \
        + p["ln_out"]["bias"].astype(jnp.float32)

    out = dense(p["wo"], shard_hint(yf.astype(x.dtype) * jax.nn.silu(g),
                                    sspec), quant, tap=tap, backend=backend)
    new_state = {"shift": x[:, -1:], "wkv": s_new}
    return out, new_state


def rwkv_channel_mix(p: Params, x: jax.Array, *,
                     quant=None,
                     state: Params | None = None, mesh=None,
                     tap: list | None = None, backend=None):
    """Squared-ReLU channel mix.  state = {"shift": [B, 1, d]}."""
    from .common import act_spec_seq, shard_hint
    B, S = x.shape[:2]
    sspec = act_spec_seq(mesh, B, S)
    prev = state["shift"] if state is not None else None
    xx = shard_hint(_token_shift(x, prev), sspec)
    sx = xx - x
    xk = shard_hint(x + sx * p["mu"][1][None, None], sspec)
    xr = shard_hint(x + sx * p["mu"][0][None, None], sspec)
    kk = jnp.square(jax.nn.relu(dense(p["wk"], xk, quant, tap=tap,
                                      backend=backend)))
    out = (jax.nn.sigmoid(dense(p["wr"], xr, None))
           * dense(p["wv"], kk, quant, tap=tap, backend=backend))
    return out, {"shift": x[:, -1:]}


def init_rwkv_state(batch: int, d_model: int, n_heads: int, head_dim: int,
                    dtype=jnp.bfloat16):
    """Fresh decode state for one RWKV layer (time-mix + channel-mix)."""
    return {
        "tm": {"shift": jnp.zeros((batch, 1, d_model), dtype),
               "wkv": jnp.zeros((batch, n_heads, head_dim, head_dim),
                                jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, 1, d_model), dtype)},
    }
