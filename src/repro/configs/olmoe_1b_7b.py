"""olmoe-1b-7b — OLMoE 1B active / 7B total [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) expert d_ff=1024 vocab=50304,
MoE 64 experts top-8.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    norm="rmsnorm",
    mlp="moe",
    n_experts=64,
    top_k=8,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab=256, mlp="moe",
        n_experts=8, top_k=2, dtype="float32")
