"""Sharded checkpoints: manifest, async save, reshard-on-load, SIGTERM."""
from .store import (
    AsyncCheckpointer,
    install_signal_handler,
    latest_step,
    list_steps,
    restore,
    save,
)

__all__ = ["AsyncCheckpointer", "install_signal_handler", "latest_step",
           "list_steps", "restore", "save"]
