"""Shared building blocks for the model zoo.

Every projection GEMM funnels through ``dense`` -> ``repro.core.quant_dense``
so W8A8 + PSUM quantization (PSQ/APSQ, any gs) is a pure config change on
any architecture — the paper's technique as a first-class framework feature.

Params are plain pytrees (dicts of arrays).  For every ``init_*`` function
there is a parallel ``*_specs`` function returning *logical axis names* per
param (same tree structure); ``repro.dist.sharding`` maps logical names to
mesh axes.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import (
    DeployedQuantState,
    QuantConfig,
    QuantState,
    deployed_dense,
    quant_dense,
    quant_params_init,
)
from repro.quant.policy import resolve_quant

Params = dict
P = jax.sharding.PartitionSpec


# ---------------------------------------------------------------------------
# Linear / norms / embeddings
# ---------------------------------------------------------------------------

def init_linear(key, shape, dtype, scale: float | None = None,
                quant=None, name: str = "") -> Params:
    """Linear weight with fan-in init; optional quantizer state.

    ``shape`` is (K, *out_dims): the first axis is the reduction dim.
    ``quant`` is a ``QuantConfig`` or a per-layer ``QuantPolicy`` resolved
    against ``name`` (the layer's stable name, stored in the state).
    """
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    w = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    resolved = resolve_quant(quant, name)
    if resolved is not None:
        p["qp"] = quant_params_init(
            w.reshape(shape[0], -1).astype(jnp.float32), resolved, name=name)
    return p


def linear_specs(logical: tuple, quant=None, name: str = "") -> Params:
    """Logical-axis names matching ``init_linear``'s tree."""
    s = {"w": logical}
    if resolve_quant(quant, name) is not None:
        # per-channel aw is 1-D over flattened out dims -> replicated
        s["qp"] = {"aw": (None,), "ax": (), "ap": (None,)}
    return s


def dense(p: Params, x: jax.Array, quant=None, *,
          tap: list | None = None, backend=None) -> jax.Array:
    """x[..., K] @ w[K, *out] with optional W8A8/APSQ fake quant.

    Dispatch is driven by the param subtree: a ``QuantState`` quantizes
    with its own resolved spec, a ``DeployedQuantState`` runs the integer
    deployment path, a legacy ``{"aw","ax","ap"}`` dict uses the global
    ``quant`` config, and no ``qp`` at all is a plain float GEMM.
    ``tap`` threads the calibration capture list down to ``quant_dense``;
    ``backend`` selects the integer execution backend (``repro.exec``)
    for deployed params.
    """
    qp = p.get("qp")
    if isinstance(qp, DeployedQuantState):
        return deployed_dense(x, qp, backend=backend)
    w = p["w"]
    if qp is None or (not isinstance(qp, QuantState)
                      and (quant is None or not quant.enabled)):
        y = jax.lax.dot_general(
            x, w.reshape(w.shape[0], -1).astype(x.dtype),
            (((x.ndim - 1,), (0,)), ((), ())),
        )
        return y.reshape(x.shape[:-1] + w.shape[1:])
    w2d = w.reshape(w.shape[0], -1)
    y = quant_dense(x, w2d, qp, quant, tap=tap)
    return y.reshape(x.shape[:-1] + w.shape[1:])


def init_norm(dim: int, dtype, kind: str = "rmsnorm") -> Params:
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def norm_specs(kind: str = "rmsnorm") -> Params:
    s = {"scale": ("norm",)}
    if kind == "layernorm":
        s["bias"] = ("norm",)
    return s


def apply_norm(p: Params, x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = xf * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def init_embedding(key, vocab: int, dim: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, dim), jnp.float32)
                      * (1.0 / math.sqrt(dim))).astype(dtype)}


def embedding_specs() -> Params:
    # "vocab_in" (not "vocab"): the input table's gather pattern interacts
    # badly with some SPMD passes, so rules can replicate it independently
    # of the output head.
    return {"table": ("vocab_in", "embed")}


# ---------------------------------------------------------------------------
# RoPE (incl. the partial/2d variant ChatGLM3 uses)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, fraction: float, theta: float):
    """Inverse frequencies for the rotary-embedded slice of the head."""
    rot_dim = int(head_dim * fraction)
    rot_dim -= rot_dim % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32)
                           / rot_dim))
    return inv, rot_dim


def apply_rope(x: jax.Array, positions: jax.Array, *, fraction: float = 1.0,
               theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding on the leading ``fraction`` of head dims.

    x: [..., S, H, head_dim]; positions: broadcastable to [..., S].
    ``fraction=0.5`` reproduces ChatGLM3's 2D-RoPE layout (rotary on the
    first half of the head, pass-through on the second half).
    """
    head_dim = x.shape[-1]
    inv, rot_dim = rope_frequencies(head_dim, fraction, theta)
    if rot_dim == 0:
        return x
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, rot/2]
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1 = xr[..., 0::2].astype(jnp.float32)
    x2 = xr[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if xp.shape[-1] else out


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, kind: str = "swiglu",
             quant=None, name: str = "") -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wi": init_linear(k1, (d_model, d_ff), dtype, quant=quant,
                              name=f"{name}.wi"),
            "wg": init_linear(k2, (d_model, d_ff), dtype, quant=quant,
                              name=f"{name}.wg"),
            "wo": init_linear(k3, (d_ff, d_model), dtype, quant=quant,
                              name=f"{name}.wo"),
        }
    return {  # gelu MLP (BERT / StarCoder2 style)
        "wi": init_linear(k1, (d_model, d_ff), dtype, quant=quant,
                          name=f"{name}.wi"),
        "wo": init_linear(k3, (d_ff, d_model), dtype, quant=quant,
                          name=f"{name}.wo"),
    }


def mlp_specs(kind: str = "swiglu", quant=None, name: str = "") -> Params:
    s = {"wi": linear_specs(("embed", "ff"), quant, f"{name}.wi"),
         "wo": linear_specs(("ff", "embed"), quant, f"{name}.wo")}
    if kind == "swiglu":
        s["wg"] = linear_specs(("embed", "ff"), quant, f"{name}.wg")
    return s


def apply_mlp(p: Params, x: jax.Array, kind: str = "swiglu",
              quant=None, tap: list | None = None,
              backend=None) -> jax.Array:
    if kind == "swiglu":
        h = (jax.nn.silu(dense(p["wg"], x, quant, tap=tap, backend=backend))
             * dense(p["wi"], x, quant, tap=tap, backend=backend))
    else:
        h = jax.nn.gelu(dense(p["wi"], x, quant, tap=tap, backend=backend))
    return dense(p["wo"], h, quant, tap=tap, backend=backend)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def shard_hint(x: jax.Array, spec) -> jax.Array:
    """with_sharding_constraint that is a no-op outside a mesh context."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def batch_axes_for(mesh, batch: int):
    """Mesh axes for the activation batch dim (divisibility-checked)."""
    if mesh is None:
        return None
    for axes in (("pod", "data"), ("data",)):
        if all(a in mesh.axis_names for a in axes):
            size = math.prod(mesh.shape[a] for a in axes)
            if batch % size == 0:
                return axes if len(axes) > 1 else axes[0]
    return None


def heads_axis_for(mesh, n: int):
    """"model" when it divides the head/feature count, else replicate."""
    if (mesh is not None and "model" in mesh.axis_names
            and n % mesh.shape["model"] == 0):
        return "model"
    return None


def act_spec_seq(mesh, batch: int, seq: int, n_trailing: int = 1):
    """Sequence-parallel constraint [B, S, ...]: S over "model".

    For attention-free regions (RWKV ddlerp, norms) whose head count does
    not divide the model axis, sharding the *sequence* over "model" keeps
    the elementwise work and its gradients 1/TP per chip instead of
    replicated (Megatron-SP adapted).
    """
    if mesh is None:
        return None
    b = batch_axes_for(mesh, batch)
    s = heads_axis_for(mesh, seq)  # "model" iff divisible
    return jax.sharding.NamedSharding(
        mesh, P(b, s, *([None] * n_trailing)))


def act_spec(mesh, batch: int, *, heads: int | None = None,
             feat: int | None = None):
    """Activation sharding constraint (NamedSharding; mesh-explicit).

    [B, S, H, hd] (heads=H)  -> P(batch, None, model?, None)
    [B, S, F]     (feat=F)   -> P(batch, None, model?)   (logits etc.)
    [B, S, D]     (neither)  -> P(batch, None, None)
    """
    if mesh is None:
        return None
    b = batch_axes_for(mesh, batch)
    if heads is not None:
        spec = P(b, None, heads_axis_for(mesh, heads), None)
    elif feat is not None:
        spec = P(b, None, heads_axis_for(mesh, feat))
    else:
        spec = P(b, None, None)
    return jax.sharding.NamedSharding(mesh, spec)


def count_params(params: Any) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params)
               if hasattr(p, "size"))
