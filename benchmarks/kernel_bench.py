"""Kernel benchmark (§III-C): APSQ Pallas kernel vs references.

On this CPU container the kernel runs in interpret mode, so wall-clock is
not a TPU signal; what we measure and report:
  * bit-exactness vs the integer oracle across a shape sweep (including
    ragged K and per-column exponent layouts),
  * oracle-vs-pallas *backend* parity + throughput side by side on one
    exported layer, at the serving shapes that matter (decode M=1,
    batched prefill) — the ``repro.exec`` path ``ServingEngine`` runs.
    Every backend record names the (block_m, block_n, exp_layout) the
    Pallas launch used and whether it came from the autotune cache or
    the heuristic, so tuned and default runs are distinguishable in
    ``BENCH_kernel.json``,
  * the m=1 decode fast path vs the generic grid (regression record for
    the single-token launch geometry),
  * the fused MoE expert grid (one pallas_call for all E experts) vs the
    per-expert unrolled launches it replaced,
  * accumulator traffic (bytes) of APSQ banks vs the INT32 baseline —
    the quantity the paper's energy claim rides on (beta 4 -> 1),
  * throughput of the jitted *fake-quant* APSQ GEMM vs plain GEMM on CPU
    (QAT-time overhead of the technique).

``--smoke`` (the CI kernel-backend job) runs the correctness sweep and
the backend parity + fast-path sections only, at reduced shapes.  Full
runs also measure the smoke shapes, so a CI smoke run can always be
floor-checked against the checked-in full-run records
(``benchmarks/check_kernel_floor.py``).

``--tune`` runs the block autotuner (``repro.kernels.autotune``) over
the benchmark shape classes first — winners land in the on-disk cache
and the backend records' ``blocks_source`` flips to "tuned".

``--json BENCH_kernel.json`` additionally emits every measurement as a
machine-readable record (throughput + parity per shape, plus jax/backend
metadata) so the perf trajectory is tracked across PRs instead of living
only in CI logs.
"""
import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantConfig, quant_dense, quant_params_init, \
    calibrate_dense
from repro.exec import backend_parity_check
from repro.kernels import autotune
from repro.kernels.apsq_matmul import (
    accumulator_vmem_bytes,
    apsq_expert_matmul_int8,
    apsq_matmul_int8,
    apsq_matmul_ref,
    choose_exps,
)
from repro.quant import export_quantized

from .common import timed


def run_correctness(print_fn=print, records: list | None = None):
    key = jax.random.PRNGKey(0)
    cells = [(32, 128, 64, 8, 2), (64, 256, 128, 4, 4),
             (16, 64, 32, 8, 1), (128, 512, 128, 16, 3),
             (8, 100, 32, 8, 2),   # ragged K -> remainder PSUM group
             (1, 192, 64, 6, 2)]   # decode shape M=1
    ok = 0
    for (m, k, n, n_p, gs) in cells:
        x = jax.random.randint(key, (m, k), -128, 128, jnp.int8)
        w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -128,
                               128, jnp.int8)
        exps = choose_exps(x, w, n_p=n_p, gs=gs)
        ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
        out = apsq_matmul_int8(x, w, exps, gs=gs, interpret=True)
        equal = bool(np.array_equal(np.asarray(ref), np.asarray(out)))
        assert equal
        ok += 1
        if records is not None:
            records.append({"section": "correctness", "m": m, "k": k,
                            "n": n, "n_p": n_p, "gs": gs,
                            "bit_exact": equal})
    print_fn(f"kernel,bit_exact_cells={ok}/{len(cells)}")
    return ok


def _backend_cells(smoke: bool):
    """(shape_name, m, k, n) cells.  The small cells always run — they are
    what CI's smoke job measures, so full runs must include them for the
    floor gate to have matching (shape, m, k, n) records to compare."""
    cells = [("decode_m1", 1, 256, 128), ("prefill", 32, 256, 128)]
    if not smoke:
        cells += [("decode_m1", 1, 1024, 512), ("prefill", 256, 1024, 512)]
    return cells


def run_backends(print_fn=print, smoke: bool = False,
                 records: list | None = None):
    """Oracle vs Pallas backend on exported layers, side by side.

    Builds the full calibrate -> export artifact (per-channel weight
    scales, so the kernel runs the [n_p, N] exponent layout) and times
    ``execute_gemm`` per backend at the decode (M=1) and prefill shapes.
    Each record carries the Pallas launch geometry actually used.
    """
    gs, n_p = 2, 8
    key = jax.random.PRNGKey(1)
    deployed = {}
    all_equal = True
    for shape_name, m, k, n in _backend_cells(smoke):
        if (k, n) not in deployed:
            xcal = jax.random.normal(key, (max(32, m), k))
            w = jax.random.normal(jax.random.fold_in(key, 2),
                                  (k, n)) * 0.05
            cfg = QuantConfig.apsq(gs=gs, n_p=n_p)
            qp = calibrate_dense(quant_params_init(w, cfg, name="lin"),
                                 xcal, w)
            dep, _ = export_quantized({"lin": {"w": w, "qp": qp}})
            deployed[(k, n)] = dep["lin"]["qp"]
        dq = deployed[(k, n)]
        x = jax.random.normal(jax.random.fold_in(key, m), (m, k))
        _, times, equal = backend_parity_check(
            dq, x, reps=2 if smoke else 5, warmup=1 if smoke else 2)
        all_equal &= equal
        blocks = autotune.get_block_config(m, k, n, n_p=n_p, gs=gs)
        print_fn(f"kernel,backend,{shape_name},M={m},K={k},N={n},"
                 f"oracle_us={times['oracle']:.0f},"
                 f"pallas_us={times['pallas']:.0f},"
                 f"bm={blocks.block_m},bn={blocks.block_n},"
                 f"{blocks.source},bit_equal={equal}")
        if records is not None:
            macs = m * k * n
            records.append({
                "section": "backend", "shape": shape_name,
                "m": m, "k": k, "n": n, "gs": gs, "n_p": n_p,
                "bit_equal": bool(equal),
                **blocks.as_record(),
                **{f"{b}_us": round(t, 1) for b, t in times.items()},
                **{f"{b}_gmacs_per_s": round(macs / t / 1e3, 3)
                   for b, t in times.items() if t > 0}})
    assert all_equal, "oracle and pallas backends disagree"
    return all_equal


def _time_eager(f, *args, reps=3, **kw):
    """Wall-clock a jitted callable (compile + warmup excluded), us."""
    jax.block_until_ready(f(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run_m1_fastpath(print_fn=print, smoke: bool = False,
                    records: list | None = None):
    """Decode regression record: the m=1 fast path (block_m=1, K unrolled
    in one grid row) vs the generic grid at the same shape — bit parity
    gates, the timing ratio is the record."""
    k, n = (256, 128) if smoke else (1024, 512)
    n_p, gs = 8, 2
    key = jax.random.PRNGKey(3)
    x = jax.random.randint(key, (1, k), -128, 128, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -128, 128,
                           jnp.int8)
    exps = choose_exps(x, w, n_p=n_p, gs=gs)
    ref = apsq_matmul_ref(x, w, exps, n_p=n_p, gs=gs)
    fast = lambda: apsq_matmul_int8(x, w, exps, gs=gs, block_m=1,
                                    interpret=True)
    generic = lambda: apsq_matmul_int8(x, w, exps, gs=gs, block_m=8,
                                       interpret=True)
    equal = bool(np.array_equal(np.asarray(ref), np.asarray(fast()))
                 and np.array_equal(np.asarray(ref), np.asarray(generic())))
    assert equal, "m=1 fast path disagrees with the oracle/generic grid"
    reps = 2 if smoke else 5
    t_fast = _time_eager(fast, reps=reps)
    t_gen = _time_eager(generic, reps=reps)
    print_fn(f"kernel,m1_fastpath,K={k},N={n},fast_us={t_fast:.0f},"
             f"generic_us={t_gen:.0f},x{t_gen / t_fast:.1f},"
             f"bit_exact={equal}")
    if records is not None:
        records.append({"section": "m1_fastpath", "m": 1, "k": k, "n": n,
                        "n_p": n_p, "gs": gs, "bit_exact": equal,
                        "fastpath_us": round(t_fast, 1),
                        "generic_us": round(t_gen, 1)})
    return equal


def run_expert_fused(print_fn=print, smoke: bool = False,
                     records: list | None = None):
    """Fused expert grid: ONE pallas_call for all E experts vs the E
    unrolled launches it replaced.  Parity gates against the per-expert
    oracle; the timing pair records the fusion win."""
    E = 4
    m, k, n = (16, 128, 64) if smoke else (64, 512, 256)
    n_p, gs = 8, 2
    key = jax.random.PRNGKey(4)
    x = jax.random.randint(key, (E, m, k), -128, 128, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (E, k, n), -128,
                           128, jnp.int8)
    exps = jnp.stack([choose_exps(x[e], w[e], n_p=n_p, gs=gs)
                      for e in range(E)])
    fused = lambda: apsq_expert_matmul_int8(x, w, exps, gs=gs,
                                            interpret=True)
    unrolled = lambda: jnp.stack([
        apsq_matmul_int8(x[e], w[e], exps[e], gs=gs, interpret=True)
        for e in range(E)])
    out = fused()
    equal = all(
        np.array_equal(
            np.asarray(apsq_matmul_ref(x[e], w[e], exps[e], n_p=n_p,
                                       gs=gs)),
            np.asarray(out[e]))
        for e in range(E))
    assert equal, "fused expert grid disagrees with the per-expert oracle"
    reps = 2 if smoke else 5
    t_fused = _time_eager(fused, reps=reps)
    t_unrolled = _time_eager(unrolled, reps=reps)
    blocks = autotune.get_block_config(m, k, n, n_p=n_p, gs=gs,
                                       expert=True)
    print_fn(f"kernel,expert_fused,E={E},M={m},K={k},N={n},"
             f"fused_us={t_fused:.0f},unrolled_us={t_unrolled:.0f},"
             f"x{t_unrolled / t_fused:.1f},bit_exact={equal}")
    if records is not None:
        records.append({"section": "expert_fused", "n_experts": E,
                        "m": m, "k": k, "n": n, "n_p": n_p, "gs": gs,
                        "bit_exact": equal, **blocks.as_record(),
                        "fused_us": round(t_fused, 1),
                        "unrolled_us": round(t_unrolled, 1)})
    return equal


def run(print_fn=print, smoke: bool = False, records: list | None = None):
    key = jax.random.PRNGKey(0)
    # 1. correctness sweep (interpret mode)
    ok = run_correctness(print_fn, records)

    # 2. execution-backend parity + throughput (the serving path)
    run_backends(print_fn, smoke=smoke, records=records)

    # 3. decode fast-path + fused-expert regression records
    run_m1_fastpath(print_fn, smoke=smoke, records=records)

    if smoke:
        return ok

    run_expert_fused(print_fn, smoke=smoke, records=records)

    # 4. accumulator bytes: the beta 4->1 story per output tile
    for gs in (1, 2, 4):
        v = accumulator_vmem_bytes(128, 128, gs)
        print_fn(f"kernel,accumulator_bytes,gs={gs},"
                 f"apsq={v['apsq_banks']},int32={v['baseline_int32']},"
                 f"saving={1 - v['apsq_banks'] / v['baseline_int32']:.2f}")
        if records is not None:
            records.append({"section": "accumulator_bytes", "gs": gs,
                            "apsq_banks": v["apsq_banks"],
                            "baseline_int32": v["baseline_int32"]})

    # 5. QAT-time overhead of fake-quant APSQ vs plain matmul (CPU)
    xf = jax.random.normal(key, (256, 1024))
    wf = jax.random.normal(jax.random.fold_in(key, 2), (1024, 512)) * 0.05
    cfg = QuantConfig.apsq(gs=2, n_p=8)
    qp = calibrate_dense(quant_params_init(wf, cfg), xf, wf, cfg)

    plain = jax.jit(lambda a, b: a @ b)
    apsq = jax.jit(lambda a, b: quant_dense(a, b, qp, cfg))
    t0, _ = timed(plain, xf, wf)
    t1, y = timed(apsq, xf, wf)
    rel = float(jnp.mean(jnp.abs(y - xf @ wf)) /
                jnp.mean(jnp.abs(xf @ wf)))
    print_fn(f"kernel,qat_overhead,plain_us={t0:.0f},apsq_us={t1:.0f},"
             f"x{t1 / t0:.1f},rel_err={rel:.4f}")
    if records is not None:
        records.append({"section": "qat_overhead", "plain_us": round(t0),
                        "apsq_us": round(t1), "rel_err": rel})

    # 6. INT8 KV-cache decode attention (second kernel): accuracy vs fp32
    #    reference + the bandwidth story (decode cells are HBM-bound).
    from repro.kernels.int8_kv_attention import (
        cache_bytes, fp_attention_ref, int8_kv_attention_f32)
    q = jax.random.normal(key, (2, 8, 64))
    kv = jax.random.normal(jax.random.fold_in(key, 3), (2, 256, 2, 64))
    vv = jax.random.normal(jax.random.fold_in(key, 4), (2, 256, 2, 64))
    L = jnp.full((2,), 256, jnp.int32)
    fp = fp_attention_ref(q, kv, vv, L)
    out = int8_kv_attention_f32(q, kv, vv, L, block_s=128, interpret=True)
    rel = float(jnp.mean(jnp.abs(out - fp)) / jnp.mean(jnp.abs(fp)))
    cb = cache_bytes(128, 32768, 4, 128)  # tinyllama decode_32k cell
    print_fn(f"kernel,int8_kv_attention,rel_err_vs_fp32={rel:.4f},"
             f"decode32k_cache_bytes: bf16={cb['bf16']:.2e} -> "
             f"int8={cb['int8']:.2e} ({cb['int8'] / cb['bf16']:.2f}x)")
    if records is not None:
        records.append({"section": "int8_kv_attention",
                        "rel_err_vs_fp32": rel,
                        "decode32k_cache_bytes": cb})
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="correctness + backend parity only (CI job)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write machine-readable records "
                         "(e.g. BENCH_kernel.json)")
    ap.add_argument("--tune", action="store_true",
                    help="run the block autotuner over the benchmark "
                         "shape classes first (winners land in the "
                         "on-disk cache; records flip to blocks_source="
                         "'tuned')")
    args = ap.parse_args(argv)
    if args.tune:
        autotune.tune_standard_shapes(verbose=True)
    records: list | None = [] if args.json else None
    run(smoke=args.smoke, records=records)
    if args.json:
        payload = {
            "benchmark": "kernel_bench",
            "smoke": bool(args.smoke),
            "unix_time": int(time.time()),
            "jax_version": jax.__version__,
            "jax_backend": jax.default_backend(),
            "platform": platform.platform(),
            "records": records,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"kernel,json -> {args.json} ({len(records)} records)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
