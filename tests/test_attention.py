"""Attention: chunked == naive, local windows, decode == full forward."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    decode_attention,
    local_attention,
    multi_head_attention,
    update_kv_cache,
)
from repro.models.common import apply_rope


def _naive(q, k, v, causal=True, window=None):
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.reshape(B, S, Hkv, G, hd),
                   k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool)) if causal else jnp.ones((S, S),
                                                                    bool)
    if window is not None:
        mask &= (jnp.arange(S)[:, None] - jnp.arange(S)[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, S, Hq, hd)


@pytest.mark.parametrize("chunk_q,chunk_kv", [(16, 16), (8, 32), (64, 64),
                                              (100, 100)])
def test_chunked_matches_naive(chunk_q, chunk_kv):
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    out = multi_head_attention(q, k, v, causal=True, chunk_q=chunk_q,
                               chunk_kv=chunk_kv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_naive(q, k, v)),
                               rtol=2e-2, atol=2e-3)


def test_chunked_non_causal():
    key = jax.random.PRNGKey(1)
    B, S, H, hd = 1, 48, 2, 8
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    out = multi_head_attention(q, k, v, causal=False, chunk_q=16,
                               chunk_kv=16)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_naive(q, k, v, causal=False)),
        rtol=2e-2, atol=2e-3)


def test_cross_attention_different_lengths():
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (2, 10, 4, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, 4, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 24, 4, 8))
    out = multi_head_attention(q, k, v, causal=False, chunk_q=4, chunk_kv=8)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(8)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2,
                               atol=2e-3)


@pytest.mark.parametrize("window", [8, 16, 33])
def test_local_matches_windowed_naive(window):
    key = jax.random.PRNGKey(3)
    B, S, Hq, Hkv, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    out = local_attention(q, k, v, window=window, chunk_q=16)
    ref = _naive(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2,
                               atol=2e-3)


def test_decode_matches_last_row():
    key = jax.random.PRNGKey(4)
    B, S, Hq, Hkv, hd = 2, 32, 4, 2, 16
    q = jax.random.normal(key, (B, S, Hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, hd))
    ref = _naive(q, k, v)
    kc = jnp.zeros((B, S, Hkv, hd))
    vc = jnp.zeros((B, S, Hkv, hd))
    kc, vc = update_kv_cache(kc, vc, k, v, 0)
    out = decode_attention(q[:, -1:], kc, vc, S - 1)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(ref[:, -1]),
                               rtol=2e-2, atol=2e-3)


def test_ring_cache_decode_matches_window():
    """Ring-buffer decode == windowed attention at the same position."""
    key = jax.random.PRNGKey(5)
    B, S, H, hd, W = 1, 40, 2, 8, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    ref = _naive(q, k, v, causal=True, window=W)
    kc = jnp.zeros((B, W, H, hd))
    vc = jnp.zeros((B, W, H, hd))
    for t in range(S):
        kc, vc = update_kv_cache(kc, vc, k[:, t:t + 1], v[:, t:t + 1], t,
                                 ring=True)
        out = decode_attention(q[:, t:t + 1], kc, vc, t, window=W, ring=True)
        np.testing.assert_allclose(np.asarray(out[:, 0]),
                                   np.asarray(ref[:, t]),
                                   rtol=2e-2, atol=2e-3)


def test_rope_rotation_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([i]))
        kj = apply_rope(k, jnp.asarray([j]))
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4


def test_rope_fraction_passthrough():
    """ChatGLM3 2D RoPE: second half of head dims untouched."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (1, 4, 1, 16))
    y = apply_rope(x, jnp.arange(4), fraction=0.5)
    np.testing.assert_allclose(np.asarray(x[..., 8:]), np.asarray(y[..., 8:]))
    assert not np.allclose(np.asarray(x[..., :8]), np.asarray(y[..., :8]))
