"""Pareto dominance over (energy, accuracy-proxy) scored candidates."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ScoredCandidate:
    """One evaluated policy: lower is better on both axes."""

    candidate: object          # candidates.Candidate
    energy_j: float            # analytical model energy (J) under policy
    error: float               # accuracy proxy (fake-quant vs fp32 oracle)
    energy_saving: float = 0.0  # vs the INT32-PSUM float baseline
    detail: dict = dataclasses.field(default_factory=dict)

    def report(self) -> dict:
        return {**self.candidate.describe(),
                "energy_j": self.energy_j, "error": self.error,
                "energy_saving": self.energy_saving, **self.detail}


def dominates(a: ScoredCandidate, b: ScoredCandidate) -> bool:
    """a dominates b: no worse on both axes, strictly better on one."""
    return (a.energy_j <= b.energy_j and a.error <= b.error
            and (a.energy_j < b.energy_j or a.error < b.error))


def pareto_front(points: list) -> list:
    """Non-dominated subset, sorted by ascending energy.

    Duplicate (energy, error) points keep only the first occurrence so a
    re-discovered candidate doesn't pad the front.
    """
    front, seen = [], set()
    for p in points:
        key = (p.energy_j, p.error)
        if key in seen:
            continue
        if any(dominates(q, p) for q in points if q is not p):
            continue
        seen.add(key)
        front.append(p)
    return sorted(front, key=lambda p: (p.energy_j, p.error))
