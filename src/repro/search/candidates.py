"""Candidate ``QuantPolicy`` generation for the (gs, n_p) co-exploration.

A candidate is an *assignment*: one ``(mode, gs, n_p)`` choice per layer
class found in the architecture's GEMM inventory (``inventory.layer_classes``
— ``*.mix.*``, ``*.ffn.*``, ``encoder.*``, ``rem.*``, ``head``...).  The
assignment is a hashable tuple so the search can dedupe across iterations;
``Candidate.policy()`` lowers it to the ``QuantPolicy`` the quant/energy/
serving stacks consume.

Generation follows the QUIDAM/MVQ playbook:
  * ``uniform_baselines`` — the global-policy anchors every heterogeneous
    candidate must beat (W8A8, APSQ at each gs, PSQ);
  * ``seed_candidates``   — structured heterogeneous points spanning the
    energy axis (attention tight / FFN loose, FFN-only, per-class grid
    corners);
  * ``mutate``            — local moves on Pareto-front members (bump one
    class's gs or n_p a step, or toggle its mode), the evolutionary
    refinement loop of ``repro.search.driver``.
"""
from __future__ import annotations

import dataclasses
import itertools
import random

from repro.core import QuantConfig
from repro.quant.policy import QuantPolicy

W8A8 = ("w8a8",)          # per-class choice: weights/activations only
MODES = ("w8a8", "apsq", "psq")


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """The per-class choice grid."""

    gs_choices: tuple = (1, 2, 4)
    n_p_choices: tuple = (4, 8, 16)

    def class_choices(self) -> list:
        """Every per-class (mode[, gs, n_p]) choice, W8A8 included."""
        out = [W8A8]
        out += [("apsq", gs, n_p) for gs, n_p
                in itertools.product(self.gs_choices, self.n_p_choices)]
        out += [("psq", 0, n_p) for n_p in self.n_p_choices]
        return out


def _choice_config(choice: tuple) -> QuantConfig:
    if choice[0] == "w8a8":
        return QuantConfig.w8a8()
    if choice[0] == "apsq":
        return QuantConfig.apsq(gs=choice[1], n_p=choice[2])
    return QuantConfig.psq(n_p=choice[2])


def _choice_label(choice: tuple) -> str:
    if choice[0] == "w8a8":
        return "w8a8"
    if choice[0] == "apsq":
        return f"apsq(gs={choice[1]},np={choice[2]})"
    return f"psq(np={choice[2]})"


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the policy search space.

    ``assignment`` is ``((class_pattern, choice), ...)`` in rule-precedence
    order; unmatched quantizable layers fall through to W8A8 so every
    candidate is at least weight/activation-quantized (the paper's QAT
    baseline).
    """

    name: str
    assignment: tuple
    origin: str = "seed"       # baseline | seed | mutation

    def policy(self) -> QuantPolicy:
        return QuantPolicy.of(
            *((pat, _choice_config(choice))
              for pat, choice in self.assignment),
            default=QuantConfig.w8a8())

    @property
    def heterogeneous(self) -> bool:
        """More than one distinct per-class choice (the RAE reconfigures)."""
        return len({choice for _, choice in self.assignment}) > 1

    def describe(self) -> dict:
        return {"name": self.name, "origin": self.origin,
                "heterogeneous": self.heterogeneous,
                "assignment": {pat: _choice_label(choice)
                               for pat, choice in self.assignment}}


@dataclasses.dataclass(frozen=True)
class FixedCandidate:
    """A hand-written ``QuantPolicy`` entered into the search as-is.

    Lets the hand-tuned ``repro.quant.policy_presets`` compete on the
    same Pareto plot as generated candidates (``cli --include-presets``).
    Not mutated — it has no per-class assignment to move in.
    """

    name: str
    fixed_policy: object         # QuantPolicy
    origin: str = "preset"

    @property
    def assignment(self) -> tuple:
        return ("fixed", self.name)

    def policy(self):
        return self.fixed_policy

    @property
    def heterogeneous(self) -> bool:
        return len(getattr(self.fixed_policy, "rules", ())) > 0

    def describe(self) -> dict:
        from .evaluate import describe_policy
        return {"name": self.name, "origin": self.origin,
                "heterogeneous": self.heterogeneous,
                "assignment": dict(describe_policy(self.fixed_policy))}


def _named(assignment: tuple, origin: str) -> Candidate:
    label = "+".join(f"{pat}={_choice_label(choice)}"
                     for pat, choice in assignment)
    return Candidate(name=label, assignment=assignment, origin=origin)


def uniform_baselines(classes: dict, space: SearchSpace) -> list:
    """Global policies: the anchors heterogeneous candidates must beat."""
    patterns = tuple(classes)
    out = []
    np_mid = space.n_p_choices[len(space.n_p_choices) // 2]
    choices = [W8A8]
    choices += [("apsq", gs, np_mid) for gs in space.gs_choices]
    choices += [("psq", 0, np_mid)]
    for choice in choices:
        assignment = tuple((p, choice) for p in patterns)
        cand = _named(assignment, "baseline")
        out.append(dataclasses.replace(
            cand, name=f"uniform_{_choice_label(choice)}"))
    return out


def seed_candidates(classes: dict, space: SearchSpace) -> list:
    """Structured heterogeneous points spanning the energy axis.

    Built from the classes actually present: attention/mix tight with FFN
    loose (the Fig. 6 sweet spot), FFN-only PSUM quantization (attention
    stays W8A8), n_p fine-vs-coarse splits, and remainder/encoder-specific
    variants when those classes exist.
    """
    patterns = tuple(classes)
    if not patterns:
        return []
    gs_lo, gs_hi = space.gs_choices[0], space.gs_choices[-1]
    np_lo, np_hi = space.n_p_choices[0], space.n_p_choices[-1]
    np_mid = space.n_p_choices[len(space.n_p_choices) // 2]

    def per_class(default, **by_pattern):
        return tuple((p, by_pattern.get(p, default)) for p in patterns)

    seeds = [
        # attention projections tight, FFN loose
        per_class(("apsq", gs_lo, np_mid),
                  **{"*.ffn.*": ("apsq", gs_hi, np_mid)}),
        # PSUM-quantize only the FFN GEMMs (the energy-dominant class)
        per_class(W8A8, **{"*.ffn.*": ("apsq", gs_lo + 1 if gs_lo + 1 in
                                       space.gs_choices else gs_lo, np_mid)}),
        # everything quantized, FFN tiled coarse (less PSUM traffic)
        per_class(("apsq", gs_lo, np_mid),
                  **{"*.ffn.*": ("apsq", gs_lo, np_lo)}),
        # fine K-tiling on mix, coarse on FFN
        per_class(("apsq", gs_lo, np_hi),
                  **{"*.ffn.*": ("apsq", gs_lo, np_lo)}),
        # PSQ on mix (independent tiles), APSQ on FFN
        per_class(("psq", 0, np_mid),
                  **{"*.ffn.*": ("apsq", gs_lo, np_mid)}),
    ]
    if "head" in classes:
        seeds.append(per_class(("apsq", gs_lo, np_mid),
                               **{"head": W8A8}))
    if "encoder.*" in classes:
        seeds.append(per_class(("apsq", gs_hi, np_mid),
                               **{"encoder.*": ("apsq", gs_lo, np_mid)}))
    if "rem.*" in classes:
        seeds.append(per_class(("apsq", gs_lo, np_mid),
                               **{"rem.*": W8A8}))
    out, seen = [], set()
    for a in seeds:
        if a not in seen:
            seen.add(a)
            out.append(_named(a, "seed"))
    return out


def mutate(candidate: Candidate, rng: random.Random,
           space: SearchSpace) -> Candidate:
    """One local move: change a random class's gs, n_p, or mode."""
    assignment = list(candidate.assignment)
    idx = rng.randrange(len(assignment))
    pat, choice = assignment[idx]
    moves = []
    if choice[0] == "apsq":
        gi = space.gs_choices.index(choice[1]) \
            if choice[1] in space.gs_choices else 0
        ni = space.n_p_choices.index(choice[2]) \
            if choice[2] in space.n_p_choices else 0
        for step in (-1, 1):
            if 0 <= gi + step < len(space.gs_choices):
                moves.append(("apsq", space.gs_choices[gi + step], choice[2]))
            if 0 <= ni + step < len(space.n_p_choices):
                moves.append(("apsq", choice[1], space.n_p_choices[ni + step]))
        moves += [W8A8, ("psq", 0, choice[2])]
    elif choice[0] == "psq":
        moves = [("apsq", space.gs_choices[0], choice[2]), W8A8]
    else:  # w8a8 -> start PSUM-quantizing this class
        moves = [c for c in space.class_choices() if c != W8A8]
    assignment[idx] = (pat, moves[rng.randrange(len(moves))])
    return _named(tuple(assignment), "mutation")
