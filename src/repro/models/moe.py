"""Mixture-of-Experts FFN with expert parallelism (EP).

Top-k token-choice routing with capacity-based dropping, the production
sharding pattern:

  * expert weights are sharded over the ``model`` mesh axis (EP);
  * tokens are sharded over the data axes and *replicated* along ``model``;
  * each device routes its local tokens to its local experts only (sort-
    based capacity dispatch — no [T, E, C] one-hot is ever materialized),
    computes, and the per-device partial outputs are combined with a
    ``psum`` over ``model``.

This trades the classical all-to-all for one reduce over ``model`` —
identical asymptotic bytes to a tensor-parallel FFN reduce, with perfectly
balanced expert storage.  On the 512-chip mesh, qwen3's 128 experts live 8
per model shard.

``moe_ffn`` is pure and mesh-free; ``moe_ffn_sharded`` wraps it in
shard_map.  The same code path (E_loc = E, no psum) runs single-device
smoke tests.  Expert GEMMs go through ``dense`` => APSQ applies to them
(per-expert K tiling), as DESIGN.md §Arch-applicability notes.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (
    DeployedQuantState,
    QuantConfig,
    QuantState,
    TapRecord,
    quant_dense,
)
from repro.quant.policy import resolve_quant
from .common import Params, dense, init_linear, linear_specs


def init_moe(key, d_model: int, d_ff: int, n_experts: int, top_k: int,
             dtype, quant=None, name: str = "") -> Params:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    sf = 1.0 / math.sqrt(d_ff)
    p = {
        "router": init_linear(kr, (d_model, n_experts), jnp.float32),
        "wi": (jax.random.normal(k1, (n_experts, d_model, d_ff), jnp.float32)
               * s).astype(dtype),
        "wg": (jax.random.normal(k2, (n_experts, d_model, d_ff), jnp.float32)
               * s).astype(dtype),
        "wo": (jax.random.normal(k3, (n_experts, d_ff, d_model), jnp.float32)
               * sf).astype(dtype),
    }
    for wname in ("wi", "wg", "wo"):
        resolved = resolve_quant(quant, f"{name}.{wname}")
        if resolved is not None:
            # One quantizer state per expert weight tensor (shared across E
            # for scale simplicity; per-expert aw columns broadcast fine).
            from repro.core import quant_params_init
            p[f"qp_{wname}"] = quant_params_init(
                p[wname][0].astype(jnp.float32), resolved,
                name=f"{name}.{wname}")
    return p


def moe_specs(quant=None, name: str = "") -> Params:
    s = {
        "router": linear_specs(("embed", None)),
        "wi": ("expert", "embed", "ff_unsharded"),
        "wg": ("expert", "embed", "ff_unsharded"),
        "wo": ("expert", "ff_unsharded", "embed"),
    }
    for wname in ("wi", "wg", "wo"):
        if resolve_quant(quant, f"{name}.{wname}") is not None:
            s[f"qp_{wname}"] = {"aw": (None,), "ax": (), "ap": (None,)}
    return s


def _expert_gemm(x, w, qp, quant, backend=None):
    """x: [E, C, K] @ w: [E, K, N] -> [E, C, N], optionally quantized.

    A ``DeployedQuantState`` ``qp`` carries stacked per-expert codes and
    exponent banks (``w`` is dropped at export) — the GEMMs run through
    the ``repro.exec`` backend registry like every other deployed linear.
    """
    if isinstance(qp, DeployedQuantState):
        from repro.exec import execute_expert_gemm
        return execute_expert_gemm(qp, x, backend=backend)
    if qp is None or (not isinstance(qp, QuantState)
                      and (quant is None or not quant.enabled)):
        return jnp.einsum("eck,ekn->ecn", x, w.astype(x.dtype))
    f = lambda xe, we: quant_dense(xe, we.astype(jnp.float32), qp, quant)
    return jax.vmap(f)(x.astype(jnp.float32), w.astype(jnp.float32)
                       ).astype(x.dtype)


def _moe_tap(tap, qp, x2d, w):
    """Capture one expert GEMM for calibration (the vmapped expert loop
    always traces, so dense-level capture cannot see these linears).

    Capacity-padded dispatch slots are all-zero rows; they are masked out
    at combine time and must not bias the activation scale low, so only
    occupied rows are captured (eager-only, dynamic shapes are fine)."""
    if (tap is not None and w is not None and isinstance(qp, QuantState)
            and not isinstance(x2d, jax.core.Tracer)):
        live = x2d[jnp.any(x2d != 0, axis=-1)]
        if live.shape[0] == 0:
            return
        tap.append(TapRecord(qp.name, live, w[0].astype(jnp.float32)
                             .reshape(w.shape[1], -1), qp))


def moe_ffn(p: Params, x: jax.Array, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25,
            quant=None,
            expert_offset: int = 0, n_local_experts: int | None = None,
            axis_name: str | None = None,
            tap: list | None = None, backend=None) -> jax.Array:
    """Top-k MoE FFN over local experts [expert_offset, +n_local).

    x: [B, S, d].  When ``axis_name`` is given the result is psum'd over
    that axis (EP combine).  Router always sees all n_experts logits.
    """
    B, S, d = x.shape
    E = n_experts
    E_loc = n_local_experts or E
    T = B * S
    xt = x.reshape(T, d)

    logits = dense(p["router"], xt.astype(jnp.float32), None)  # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, top_k)                   # [T, k]
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    # --- capacity dispatch over local experts (sort-based, no one-hot) ---
    cap = int(math.ceil(T * top_k / E * capacity_factor))
    e_flat = topi.reshape(T * top_k) - expert_offset           # local ids
    t_flat = jnp.repeat(jnp.arange(T), top_k)
    w_flat = topw.reshape(T * top_k)
    local = (e_flat >= 0) & (e_flat < E_loc)
    e_key = jnp.where(local, e_flat, E_loc)  # non-local sorts to the end

    order = jnp.argsort(e_key, stable=True)
    e_sort, t_sort, w_sort = e_key[order], t_flat[order], w_flat[order]
    # rank of each entry within its expert = position - first position
    counts = jnp.bincount(e_sort, length=E_loc + 1)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)])[:-1]
    rank = jnp.arange(T * top_k) - starts[e_sort]
    keep = (e_sort < E_loc) & (rank < cap)
    slot = jnp.where(keep, e_sort * cap + rank, E_loc * cap)   # overflow slot

    buf = jnp.zeros((E_loc * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xt[t_sort], 0))
    h = buf[:-1].reshape(E_loc, cap, d)

    # --- expert computation (swiglu) ---
    _moe_tap(tap, p.get("qp_wg"), h.reshape(-1, d), p.get("wg"))
    _moe_tap(tap, p.get("qp_wi"), h.reshape(-1, d), p.get("wi"))
    a = _expert_gemm(h, p.get("wg"), p.get("qp_wg"), quant, backend)
    b = _expert_gemm(h, p.get("wi"), p.get("qp_wi"), quant, backend)
    hidden = jax.nn.silu(a) * b
    _moe_tap(tap, p.get("qp_wo"), hidden.reshape(-1, hidden.shape[-1]),
             p.get("wo"))
    y_exp = _expert_gemm(hidden, p.get("wo"), p.get("qp_wo"), quant, backend)

    # --- combine back to tokens ---
    y_flat = jnp.concatenate(
        [y_exp.reshape(E_loc * cap, d), jnp.zeros((1, d), y_exp.dtype)])
    y_tok = y_flat[slot] * jnp.where(keep, w_sort, 0.0)[:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[t_sort].add(y_tok)

    if axis_name is not None:
        y = jax.lax.psum(y, axis_name)
    return y.reshape(B, S, d)


def moe_ffn_sharded(p: Params, x: jax.Array, *, mesh, n_experts: int,
                    top_k: int, capacity_factor: float = 1.25,
                    quant: QuantConfig | None = None,
                    data_axes=("pod", "data"), model_axis="model",
                    backend=None):
    """EP via shard_map: tokens sharded over data axes, experts over model.

    Falls back to the pure version when mesh is None (smoke tests).
    Deployed expert banks (``qp_*`` as stacked ``DeployedQuantState``)
    shard their leading expert axis over ``model`` like the float experts.
    """
    if mesh is None:
        return moe_ffn(p, x, n_experts=n_experts, top_k=top_k,
                       capacity_factor=capacity_factor, quant=quant,
                       backend=backend)

    data_axes = tuple(a for a in data_axes if a in mesh.axis_names)
    m = mesh.shape[model_axis]
    assert n_experts % m == 0, (n_experts, m)
    e_loc = n_experts // m

    def _expert_param_spec(k, v):
        if k in ("wi", "wg", "wo") or isinstance(v, DeployedQuantState):
            return jax.tree.map(lambda _: P(model_axis), v)
        return jax.tree.map(lambda _: P(), v)

    in_specs = (
        jax.tree.map(lambda _: P(), p["router"]),
        {k: _expert_param_spec(k, v)
         for k, v in p.items() if k != "router"},
        P(data_axes, None, None),
    )

    def local_fn(router, experts, xl):
        idx = jax.lax.axis_index(model_axis)
        pl = dict(experts)
        pl["router"] = router
        return moe_ffn(pl, xl, n_experts=n_experts, top_k=top_k,
                       capacity_factor=capacity_factor, quant=quant,
                       expert_offset=idx * e_loc, n_local_experts=e_loc,
                       axis_name=model_axis, backend=backend)

    from repro.dist import shard_map
    f = shard_map(
        local_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=P(data_axes, None, None),
    )
    experts = {k: v for k, v in p.items() if k != "router"}
    return f(p["router"], experts, x)
