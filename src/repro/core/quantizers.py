"""Quantizers used throughout the APSQ framework.

Implements (paper §II-B):
  * ``round_ste``      — rounding with a straight-through gradient [24].
  * ``lsq_quantize``   — Learned Step Size Quantization (LSQ) [10] fake
    quantization.  The gradient w.r.t. the learned scale ``alpha`` follows
    directly from expressing the quantizer with ``round_ste`` and letting
    autodiff do the rest (this reproduces LSQ eq. (3) exactly).
  * ``po2_scale``      — power-of-two scale ``2^round(log2_alpha)`` learned
    via STE so re-scaling lowers to a hardware shift (paper §II-B).
  * ``grad_scale``     — LSQ gradient-scale trick ``g = 1/sqrt(N*Qp)``.

All functions are pure and jit/vmap/scan friendly; QAT operates on floats
("fake quant"): values are snapped to the integer grid but kept in the
compute dtype.  The Pallas deployment kernel (kernels/apsq_matmul) does the
true-integer version and is tested bit-exact against these semantics.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp


def qrange(bits: int, signed: bool = True) -> tuple[int, int]:
    """(Qn, Qp) clip bounds for a ``bits``-wide integer grid."""
    if signed:
        return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return 0, 2**bits - 1


def round_ste(x: jax.Array) -> jax.Array:
    """Round-to-nearest-even with identity (straight-through) gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def floor_ste(x: jax.Array) -> jax.Array:
    """Floor with identity gradient (used for power-of-two exponents)."""
    return x + jax.lax.stop_gradient(jnp.floor(x) - x)


def round_half_up_ste(x: jax.Array) -> jax.Array:
    """Round-half-up (toward +inf) with identity gradient.

    This is the rounding the RAE's shift-based PSUM quantizer implements
    (``kernels/apsq_matmul/ref.rshift_round``: ``(v + 2^(e-1)) >> e`` ==
    ``floor(v/2^e + 0.5)``) — the PSUM fake quantizer uses it so QAT and
    the integer deployment path agree bit-for-bit on the PO2 grid.
    """
    return x + jax.lax.stop_gradient(jnp.floor(x + 0.5) - x)


def grad_scale(x: jax.Array, scale) -> jax.Array:
    """Forward identity; gradient multiplied by ``scale`` (LSQ trick)."""
    return x * scale + jax.lax.stop_gradient(x * (1.0 - scale))


def lsq_gradient_scale(numel: int, qp: int) -> float:
    """LSQ paper's per-quantizer gradient scale g = 1/sqrt(numel * Qp)."""
    return 1.0 / math.sqrt(max(int(numel) * int(qp), 1))


def lsq_quantize(
    x: jax.Array,
    alpha: jax.Array,
    bits: int = 8,
    signed: bool = True,
    g: float | None = None,
) -> jax.Array:
    """LSQ fake quantization: ``alpha * round(clip(x/alpha, Qn, Qp))``.

    ``alpha`` may be scalar (per-tensor) or broadcastable (per-channel).
    ``g`` is the LSQ gradient scale; if None it is derived from x.size.
    """
    qn, qp = qrange(bits, signed)
    if g is None:
        g = lsq_gradient_scale(x.size, qp)
    alpha = grad_scale(alpha, g)
    # Clip with STE-through-boundary exactly as LSQ: gradients to x pass only
    # inside the clip range; gradients to alpha accumulate from the rounding
    # residual inside and the saturation value outside.  jnp.clip + round_ste
    # reproduces this under autodiff.
    scaled = x / alpha
    clipped = jnp.clip(scaled, qn, qp)
    return round_ste(clipped) * alpha


def po2_scale(log2_alpha: jax.Array) -> jax.Array:
    """Effective power-of-two scale ``2^floor(log2_alpha)`` with STE.

    The paper (§II-B) forces PSUM scaling factors to power-of-two by
    learning ``2^{floor(log2 alpha)}`` through a straight-through estimator,
    replacing the dequant multiply by a shift in hardware.
    """
    return jnp.exp2(floor_ste(log2_alpha))


def po2_quantize(
    x: jax.Array,
    log2_alpha: jax.Array,
    bits: int = 8,
    signed: bool = True,
    g: float | None = None,
) -> jax.Array:
    """Fake quantization with a learned power-of-two scale (PSUM quantizer).

    Equivalent to ``lsq_quantize`` but the scale is snapped to 2^k so that
    dequantization is a bit-shift in the RAE / Pallas kernel, and rounding
    is half-up to match the hardware shifter exactly (so the QAT forward
    and the integer deployment path agree bit-for-bit on the PO2 grid).
    """
    qn, qp = qrange(bits, signed)
    if g is None:
        g = lsq_gradient_scale(x.size, qp)
    log2_alpha = grad_scale(log2_alpha, g)
    alpha = po2_scale(log2_alpha)
    clipped = jnp.clip(x / alpha, qn, qp)
    return round_half_up_ste(clipped) * alpha


def po2_quantize_codes(x: jax.Array, log2_alpha: jax.Array, bits: int = 8):
    """Integer codes + shift exponent (deployment view, no gradients)."""
    qn, qp = qrange(bits, True)
    exp = jnp.floor(log2_alpha).astype(jnp.int32)
    alpha = jnp.exp2(exp.astype(x.dtype))
    codes = jnp.clip(jnp.round(x / alpha), qn, qp).astype(jnp.int8)
    return codes, exp


def init_alpha_from(x: jax.Array, bits: int = 8, signed: bool = True) -> jax.Array:
    """LSQ initialization: alpha = 2*mean(|x|)/sqrt(Qp)."""
    _, qp = qrange(bits, signed)
    return 2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(float(qp)) + 1e-12


def init_log2_alpha_from(x: jax.Array, bits: int = 8) -> jax.Array:
    """PO2 variant of LSQ init (log2 domain)."""
    return jnp.log2(init_alpha_from(x, bits))


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of one quantizer (used by configs & model surgery)."""

    bits: int = 8
    signed: bool = True
    po2: bool = False  # power-of-two scale (PSUM quantizers)

    @property
    def qn(self) -> int:
        return qrange(self.bits, self.signed)[0]

    @property
    def qp(self) -> int:
        return qrange(self.bits, self.signed)[1]
