"""Pure-jnp integer oracle for the APSQ matmul kernel.

True-integer semantics of Algorithm 1 (paper §III), exactly as the
Reconfigurable APSQ Engine (RAE) executes it in hardware and as the Pallas
kernel executes it on TPU:

  * activations / weights are INT8 codes; each K-tile product accumulates in
    INT32 (the MXU's native int8xint8->int32 path),
  * every stored PSUM is an INT8 code with a power-of-two scale ``2^e_i``
    (in product-scale units), so quantization is an arithmetic right-shift
    with round-half-up and dequantization is a left-shift — matching the
    RAE's shifter-based quant/dequant modules,
  * group starts apply APSQ (accumulate the previous group's dequantized
    codes + the fresh product, then requantize), tails apply plain PSQ,
  * the final tile is requantized once more and dequantized to INT32.

All functions are shape-polymorphic jnp code (no Pallas) and serve as the
bit-exact oracle for ``kernel.py`` in interpret mode and on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MIN, INT8_MAX = -128, 127


def rshift_round(v: jax.Array, e: jax.Array) -> jax.Array:
    """Arithmetic right-shift by ``e`` with round-half-up (RAE shifter).

    ``e`` may be 0 (identity).  Implemented as ``(v + 2^(e-1)) >> e`` which is
    exact integer round-half-up toward +inf, the cheapest faithful rounding a
    shift-based hardware quantizer implements.
    """
    v = v.astype(jnp.int32)
    e = jnp.asarray(e, jnp.int32)
    bias = jnp.where(e > 0, jnp.left_shift(1, jnp.maximum(e - 1, 0)), 0)
    return jnp.where(e > 0, jnp.right_shift(v + bias, e), v)


def quantize_psum(v: jax.Array, e: jax.Array) -> jax.Array:
    """INT32 PSUM -> INT8 code at scale 2^e (shift + clip)."""
    return jnp.clip(rshift_round(v, e), INT8_MIN, INT8_MAX).astype(jnp.int8)


def dequantize_psum(code: jax.Array, e: jax.Array) -> jax.Array:
    """INT8 code at scale 2^e -> INT32 value in product-scale units."""
    return jnp.left_shift(code.astype(jnp.int32), jnp.asarray(e, jnp.int32))


def pad_ragged_k(x_codes: jax.Array, w_codes: jax.Array, n_p: int):
    """Zero-pad K up to ``n_p * ceil(K / n_p)`` (remainder PSUM group).

    Zero codes contribute nothing to any partial sum, so a ragged final
    K-tile behaves exactly like a full tile whose trailing channels are
    masked out — the "zero-contribution" remainder group.
    """
    k = x_codes.shape[1]
    pad = (-k) % n_p
    if pad:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, pad)))
        w_codes = jnp.pad(w_codes, ((0, pad), (0, 0)))
    return x_codes, w_codes


def psum_tiles(x_codes: jax.Array, w_codes: jax.Array, n_p: int) -> jax.Array:
    """[n_p, M, N] INT32 partial-sum tiles of ``x @ w`` split along K.

    Ragged ``K % n_p`` is handled by zero-padding the final tile
    (``pad_ragged_k``), so any (K, n_p) combination is legal.
    """
    x_codes, w_codes = pad_ragged_k(x_codes, w_codes, n_p)
    m, k = x_codes.shape
    n = w_codes.shape[1]
    kt = k // n_p
    xt = x_codes.reshape(m, n_p, kt).astype(jnp.int32)
    wt = w_codes.reshape(n_p, kt, n).astype(jnp.int32)
    return jnp.einsum("mpk,pkn->pmn", xt, wt)


def apsq_matmul_ref(
    x_codes: jax.Array,
    w_codes: jax.Array,
    exps: jax.Array,
    *,
    n_p: int,
    gs: int,
) -> jax.Array:
    """Oracle: INT8 x INT8 GEMM with Algorithm-1 PSUM handling.

    x_codes: [M, K] int8, w_codes: [K, N] int8, exps: [n_p] int32 shift
    exponents (product-scale units, >= 0).  Returns the dequantized output
    tile as INT32 in product-scale units: ``T_o = AP*_{n_p-1} << e_{n_p-1}``.
    """
    assert gs >= 1
    tiles = psum_tiles(x_codes, w_codes, n_p)
    stored: list = [None] * n_p
    for i in range(0, n_p, gs):  # group starts
        acc = tiles[i]
        for j in range(max(0, i - gs), i):  # previous group's stored codes
            acc = acc + dequantize_psum(stored[j], exps[j])
        code = quantize_psum(acc, exps[i])  # APSQ
        stored[i] = code
        if i == n_p - 1:
            return dequantize_psum(code, exps[i])
        for j in range(i + 1, min(i + gs, n_p)):
            if j < n_p - 1:
                stored[j] = quantize_psum(tiles[j], exps[j])  # PSQ tail
            else:  # final tile closes out mid-group
                acc = tiles[j]
                for l in range(i, n_p - 1):
                    acc = acc + dequantize_psum(stored[l], exps[l])
                code = quantize_psum(acc, exps[j])
                return dequantize_psum(code, exps[j])
    raise AssertionError("unreachable")


def baseline_matmul_ref(x_codes: jax.Array, w_codes: jax.Array) -> jax.Array:
    """INT32-accumulator W8A8 GEMM (the high-precision-PSUM baseline)."""
    return jax.lax.dot_general(
        x_codes.astype(jnp.int32),
        w_codes.astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def choose_exps(
    x_codes: jax.Array, w_codes: jax.Array, *, n_p: int, gs: int
) -> jax.Array:
    """Calibration helper: per-tile exponents from running-PSUM magnitudes.

    Mirrors ``core.layers.calibrate_dense`` in integer domain: exponent e_i
    is the smallest shift such that the running accumulation the quantizer
    actually sees fits INT8.  Used by tests and by ``ops.quantize_operands``.
    """
    tiles = psum_tiles(x_codes, w_codes, n_p)
    running = jnp.cumsum(tiles, axis=0)  # upper bound on any AP_i magnitude
    mags = jnp.max(jnp.abs(running), axis=(1, 2))
    exps = jnp.ceil(jnp.log2(jnp.maximum(mags, 1) / INT8_MAX)).astype(jnp.int32)
    return jnp.maximum(exps, 0)
