"""Execution-backend layer: registry, execute_gemm parity, kernel serving.

The acceptance bar for the backend subsystem: every deployed projection
GEMM — dense linears (per-channel scales), MoE expert banks, the
tied-embedding head — dispatches through ``repro.exec.execute_gemm``, and
``ServingEngine.from_exported(backend="pallas")`` greedy-decodes
token-for-token identically to ``backend="oracle"``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeployedQuantState, QuantConfig, quant_params_init, \
    calibrate_dense
from repro.exec import (
    AutoBackend,
    ExecBackend,
    PallasBackend,
    available_backends,
    execute_expert_gemm,
    execute_gemm,
    get_backend,
    register_backend,
)
from repro.models.config import ModelConfig
from repro.models.model import forward, init_lm
from repro.quant import QuantPolicy, calibrate_model, export_quantized, \
    snap_params_po2


def _cfg(**kw):
    base = dict(name="ex", family="dense", n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=64, dtype="float32",
                scan_layers=False, quant=QuantConfig.apsq(gs=2, n_p=4))
    base.update(kw)
    return ModelConfig(**base)


def _exported_linear(key, m=8, k=32, n=16, per_channel=True,
                     psum=QuantConfig.apsq(gs=2, n_p=4).psum):
    cfg = QuantConfig(enabled=True, per_channel_w=per_channel, psum=psum)
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    qp = calibrate_dense(quant_params_init(w, cfg, name="lin"), x, w)
    dep, _ = export_quantized({"lin": {"w": w, "qp": qp}})
    return x, dep["lin"]["qp"]


# ------------------------------ registry -----------------------------------

def test_registry_and_resolution():
    assert {"auto", "oracle", "pallas"} <= set(available_backends())
    assert get_backend("oracle").name == "oracle"
    assert get_backend(None).name == "auto"
    inst = PallasBackend(interpret=True)
    assert get_backend(inst) is inst  # instances pass through
    with pytest.raises(KeyError, match="unknown exec backend"):
        get_backend("does-not-exist")
    # auto resolves to a leaf backend (oracle on CPU CI)
    leaf = AutoBackend().resolve()
    assert leaf.name in ("oracle", "pallas")
    # custom registration
    class Custom(ExecBackend):
        name = "custom-test"
        def int_gemm(self, x_codes, w_codes, psum_exps, *, gs):
            return get_backend("oracle").int_gemm(
                x_codes, w_codes, psum_exps, gs=gs)
    register_backend("custom-test", Custom())
    assert get_backend("custom-test").name == "custom-test"


# ------------------------------ execute_gemm -------------------------------

@pytest.mark.parametrize("per_channel", [False, True])
def test_execute_gemm_backend_parity(per_channel):
    """oracle == pallas (interpret) on exported layers, both exponent
    layouts ([n_p] per-tensor and [n_p, N] per-channel)."""
    x, dq = _exported_linear(jax.random.PRNGKey(0), per_channel=per_channel)
    assert dq.psum_exps.ndim == (2 if per_channel else 1)
    y_o = execute_gemm(dq, x, backend="oracle")
    y_p = execute_gemm(dq, x, backend=PallasBackend(interpret=True))
    np.testing.assert_array_equal(np.asarray(y_o), np.asarray(y_p))


def test_execute_gemm_flattens_leading_dims():
    """[B, T, K] activations flatten to one [M, K] GEMM; decode's
    [B, 1, K] shape (M = B) works on both backends."""
    x, dq = _exported_linear(jax.random.PRNGKey(1))
    for shape in ((2, 4, 32), (3, 1, 32)):
        xb = jnp.broadcast_to(x[0], shape)
        y_o = execute_gemm(dq, xb, backend="oracle")
        y_p = execute_gemm(dq, xb, backend="pallas")
        assert y_o.shape == shape[:-1] + dq.out_dims
        np.testing.assert_array_equal(np.asarray(y_o), np.asarray(y_p))


def test_execute_gemm_w8a8_baseline_path():
    """psum_exps=None (plain W8A8 export) runs the baseline integer GEMM
    on both backends."""
    x, dq = _exported_linear(
        jax.random.PRNGKey(2),
        psum=QuantConfig.w8a8().psum)
    assert dq.psum_exps is None
    y_o = execute_gemm(dq, x, backend="oracle")
    y_p = execute_gemm(dq, x, backend="pallas")
    np.testing.assert_array_equal(np.asarray(y_o), np.asarray(y_p))


def test_execute_gemm_under_jit_and_vmap():
    x, dq = _exported_linear(jax.random.PRNGKey(3))
    f = jax.jit(lambda a: execute_gemm(dq, a, backend="pallas"))
    np.testing.assert_array_equal(
        np.asarray(f(x)), np.asarray(execute_gemm(dq, x, backend="oracle")))
    xb = jnp.stack([x, x * 0.5])
    yb = jax.vmap(lambda a: execute_gemm(dq, a, backend="pallas"))(xb)
    np.testing.assert_array_equal(
        np.asarray(yb[0]), np.asarray(execute_gemm(dq, x, backend="oracle")))


# ------------------------------ MoE expert banks ---------------------------

def test_moe_expert_bank_export_and_parity():
    """Expert tensors export to stacked DeployedQuantState (per-expert
    codes + exponent banks); execute_expert_gemm matches per-expert
    execute_gemm on both backends."""
    cfg = _cfg(mlp="moe", n_experts=4, top_k=2)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    dep, report = export_quantized(p2)
    ffn = dep["units"]["u0"]["0"]["ffn"]
    dq = ffn["qp_wi"]
    assert isinstance(dq, DeployedQuantState)
    assert "wi" not in ffn  # float expert bank dropped
    E = cfg.n_experts
    assert dq.w_codes.shape[0] == E and dq.psum_exps.shape[0] == E
    assert report["unit.0.ffn.wi"]["n_experts"] == E

    x = jax.random.normal(jax.random.PRNGKey(2), (E, 3, cfg.d_model))
    y_o = execute_expert_gemm(dq, x, backend="oracle")
    y_p = execute_expert_gemm(dq, x, backend="pallas")
    np.testing.assert_array_equal(np.asarray(y_o), np.asarray(y_p))
    # per-expert slicing is exactly execute_gemm on each expert's codes
    import dataclasses
    for e in range(E):
        dqe = dataclasses.replace(
            dq, w_codes=dq.w_codes[e], ax_exp=dq.ax_exp[e],
            aw_exp=dq.aw_exp[e], psum_exps=dq.psum_exps[e])
        np.testing.assert_array_equal(
            np.asarray(y_o[e]),
            np.asarray(execute_gemm(dqe, x[e], backend="oracle")))


def test_moe_scan_stacked_expert_export_and_decode():
    """scan_layers=True (the default; olmoe/qwen3 shape): expert weights
    are [n_units, E, K, N] and must still export to per-expert deployed
    banks — regression for the export walk silently keeping float
    experts on stacked trees."""
    cfg = _cfg(mlp="moe", n_experts=4, top_k=2, scan_layers=True,
               n_layers=2)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    dep, report = export_quantized(p2)
    ffn = dep["units"]["0"]["ffn"]
    dq = ffn["qp_wi"]
    assert isinstance(dq, DeployedQuantState), type(dq)
    assert "wi" not in ffn
    assert dq.w_codes.shape[:2] == (cfg.n_units, cfg.n_experts)
    assert report["unit.0.ffn.wi"]["n_experts"] == cfg.n_experts
    # deployed forward (scan over units slices the expert banks per unit)
    lg_o = forward(dep, cfg, tok, backend="oracle")
    lg_p = forward(dep, cfg, tok, backend="pallas")
    np.testing.assert_array_equal(np.asarray(lg_o), np.asarray(lg_p))
    lg_fake = forward(snap_params_po2(p2), cfg, tok)
    err = float(jnp.max(jnp.abs(lg_o - lg_fake)))
    ref = float(jnp.max(jnp.abs(lg_fake))) + 1e-6
    assert err / ref < 0.05, (err, ref)


def test_moe_deployed_forward_matches_snapped_fakequant():
    cfg = _cfg(mlp="moe", n_experts=4, top_k=2)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    dep, _ = export_quantized(p2)
    lg_dep = forward(dep, cfg, tok, backend="oracle")
    lg_fake = forward(snap_params_po2(p2), cfg, tok)
    err = float(jnp.max(jnp.abs(lg_dep - lg_fake)))
    ref = float(jnp.max(jnp.abs(lg_fake))) + 1e-6
    assert err / ref < 0.05, (err, ref)


# ------------------------------ tied-embedding head ------------------------

def test_tied_head_calibrates_exports_and_serves():
    cfg = _cfg(tie_embeddings=True)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    assert "head" not in p  # tied: no separate head weight
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    qp_head = p2["embed"]["qp_head"]
    assert qp_head.name == "head" and qp_head.ap is not None
    dep, report = export_quantized(p2)
    dq = dep["embed"]["qp_head"]
    assert isinstance(dq, DeployedQuantState)
    assert report["head"]["tied_head"] and report["head"]["mode"] == "apsq"
    # the float table must survive for the input embedding lookup
    np.testing.assert_array_equal(np.asarray(dep["embed"]["table"]),
                                  np.asarray(p2["embed"]["table"]))
    # deployed logits == snapped fake-quant logits (same PO2 grid)
    lg_dep = forward(dep, cfg, tok, backend="oracle")
    lg_pal = forward(dep, cfg, tok, backend="pallas")
    np.testing.assert_array_equal(np.asarray(lg_dep), np.asarray(lg_pal))
    lg_fake = forward(snap_params_po2(p2), cfg, tok)
    err = float(jnp.max(jnp.abs(lg_dep - lg_fake)))
    ref = float(jnp.max(jnp.abs(lg_fake))) + 1e-6
    assert err / ref < 0.05, (err, ref)


# ------------------------------ kernel serving -----------------------------

def test_engine_pallas_decode_equals_oracle_decode():
    """The tentpole acceptance: ServingEngine.from_exported with
    backend="pallas" (interpret mode on CPU) greedy-decodes
    token-for-token identically to backend="oracle"."""
    from repro.serving import Request, ServingEngine
    cfg = _cfg(tie_embeddings=True)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    prompt = np.arange(5) % cfg.vocab
    outs = {}
    for be in ("oracle", PallasBackend(interpret=True)):
        eng = ServingEngine.from_exported(p2, cfg, max_batch=1, cache_len=32,
                                          prefill_chunk=8, backend=be)
        done = eng.run([Request(uid=0, tokens=prompt, max_new_tokens=4)])
        outs[getattr(be, "name", be)] = done[0].out
    assert outs["oracle"] == outs["pallas"], outs


def test_engine_auto_backend_matches_oracle_on_cpu():
    """backend="auto" (the default) resolves to the oracle on CPU — the
    engine serves identically with no knob set."""
    from repro.serving import Request, ServingEngine
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    prompt = np.arange(4) % cfg.vocab
    outs = {}
    for be in ("auto", "oracle"):
        eng = ServingEngine.from_exported(p2, cfg, max_batch=1, cache_len=32,
                                          prefill_chunk=8, backend=be)
        outs[be] = eng.run([Request(uid=0, tokens=prompt,
                                    max_new_tokens=4)])[0].out
    assert outs["auto"] == outs["oracle"]


# ---------------------------------------------------------------------------
# kv_attention: the second op family
# ---------------------------------------------------------------------------

def test_kv_attention_op_family_backend_parity():
    """execute_kv_attention dispatches per backend; oracle == pallas ==
    auto (auto resolves to oracle on CPU) within interpret tolerance."""
    from repro.exec import execute_kv_attention
    from repro.kernels.int8_kv_attention import quantize_kv_po2

    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (2, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 16))
    kc, ke = quantize_kv_po2(k)
    vc, ve = quantize_kv_po2(v)
    L = jnp.asarray([17, 64], jnp.int32)
    outs = {be: execute_kv_attention(q, kc, vc, ke, ve, L, block_s=32,
                                     backend=be)
            for be in ("oracle", "pallas", "auto")}
    np.testing.assert_allclose(np.asarray(outs["pallas"]),
                               np.asarray(outs["oracle"]),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_array_equal(np.asarray(outs["auto"]),
                                  np.asarray(outs["oracle"]))


def test_kv_attention_scalar_length_and_block_rounding():
    """Scalar lengths broadcast; a non-dividing block_s is rounded down
    to a divisor of S instead of erroring (kv_block_size)."""
    from repro.exec import execute_kv_attention, kv_block_size
    from repro.kernels.int8_kv_attention import quantize_kv_po2

    assert kv_block_size(96, 512) == 96
    assert kv_block_size(96, 64) == 48
    assert kv_block_size(7, 4) == 1

    key = jax.random.PRNGKey(10)
    q = jax.random.normal(key, (1, 4, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 48, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 48, 2, 8))
    kc, ke = quantize_kv_po2(k)
    vc, ve = quantize_kv_po2(v)
    a = execute_kv_attention(q, kc, vc, ke, ve, 20, block_s=20,
                             backend="pallas")
    b = execute_kv_attention(q, kc, vc, ke, ve,
                             jnp.asarray([20], jnp.int32), backend="oracle")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-6)


def _stacked_expert_state(key, n_experts, *, k=32, n=16, per_channel=True):
    """Stack E independently-exported linears into one expert-bank
    DeployedQuantState (what export_quantized emits for MoE wi/wo)."""
    import dataclasses
    xs, dqs = zip(*[_exported_linear(jax.random.fold_in(key, e), k=k, n=n,
                                     per_channel=per_channel)
                    for e in range(n_experts)])
    dq = dataclasses.replace(
        dqs[0],
        w_codes=jnp.stack([d.w_codes for d in dqs]),
        ax_exp=jnp.stack([d.ax_exp for d in dqs]),
        aw_exp=jnp.stack([d.aw_exp for d in dqs]),
        psum_exps=jnp.stack([d.psum_exps for d in dqs]))
    return jnp.stack(xs), dq, dqs


@pytest.mark.parametrize("n_experts", [1, 4, 8])
@pytest.mark.parametrize("per_channel,k", [(True, 32), (False, 45)])
def test_execute_expert_gemm_fused_equals_unrolled(n_experts, per_channel,
                                                   k):
    """The single fused expert launch == manually unrolled per-expert
    execute_gemm calls, on both backends, across expert counts, ragged K
    (45 % n_p != 0) and per-column exponent banks."""
    import dataclasses
    x, dq, dqs = _stacked_expert_state(
        jax.random.PRNGKey(11 + n_experts), n_experts, k=k,
        per_channel=per_channel)
    y_o = execute_expert_gemm(dq, x, backend="oracle")
    y_p = execute_expert_gemm(dq, x, backend=PallasBackend(interpret=True))
    np.testing.assert_array_equal(np.asarray(y_o), np.asarray(y_p))
    for e in range(n_experts):
        y_ref = execute_gemm(dqs[e], x[e], backend="oracle")
        np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_o[e]))


def test_expert_gemm_block_overrides_keep_parity():
    """PallasBackend block_overrides change launch geometry only — the
    fused expert output stays bit-identical."""
    from repro.kernels.autotune import BlockConfig
    x, dq, _ = _stacked_expert_state(jax.random.PRNGKey(17), 4)
    base = execute_expert_gemm(dq, x, backend=PallasBackend(interpret=True))
    pinned = execute_expert_gemm(
        dq, x, backend=PallasBackend(
            interpret=True,
            block_overrides={"expert": BlockConfig(8, 128,
                                                   source="override")}))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(pinned))
