"""Serving engines: prefill/decode split with continuous batching.

Two engines share the Request/step/run host API:

``ServingEngine`` — dense float KV caches, one [max_batch, cache_len]
cache per attention layer.  The simple reference path.

``PagedServingEngine`` — the production path: every attention layer's
cache is a pool of fixed-size INT8 pages (``repro.serving.paged_cache``)
with per-(slot, kv-head) power-of-two scales, shared by all request
slots through a page table.  Pages are allocated on demand and reclaimed
on finish/eviction by the host-side ``repro.serving.scheduler``; the
attention read path is the ``kv_attention`` exec op family, i.e. the
``kernels/int8_kv_attention`` flash-decode Pallas kernel on TPU and its
jnp oracle elsewhere.  Because a slot's running exponents depend only on
its own tokens, greedy decodes are token-identical regardless of which
other requests share the pool — admission and eviction mid-decode never
change anyone's output.

Production pattern (vLLM-style, TPU-adapted):
  * fixed-shape request slots (``max_batch``) so every decode step hits the
    same compiled executable — no shape churn;
  * chunked parallel prefill (paged engine): prompts are processed in
    chunks of up to ``prefill_chunk`` tokens, one batched forward per
    chunk (``forward_paged_chunk``) — every non-attention GEMM runs once
    at m=chunk and attention attends the whole chunk against the paged
    cache with an in-chunk causal mask.  The chunk's quantized KV goes
    through the same per-token bump-rescale recurrence as decode, so the
    resulting cache (codes AND exponents) is bit-identical to the old
    token-by-token scan.  Chunk sizes are snapped to powers of two, so
    the chunk body compiles for at most log2(prefill_chunk)+1 shapes;
  * token-budget steps: each engine heartbeat spends up to
    ``prefill_token_budget`` prompt tokens (default: ``prefill_chunk *
    max_batch`` — one chunk per slot) on mid-prefill slots before running
    the decode batch, so prefill of long prompts interleaves with
    in-flight decodes instead of stalling them.  Raise ``prefill_chunk``
    for prompt-heavy loads — TTFT drops roughly with the chunk count per
    prompt; lower the budget when decode-latency jitter matters more
    than TTFT (a budget of one chunk serializes prompt admission across
    slots and multiplies TTFT by the mid-prefill slot count);
  * fused decode horizon: every heartbeat runs up to ``decode_horizon``
    (pow2, default 8 on the paged engine) decode steps inside ONE jitted
    ``lax.scan`` macro-step — greedy/sampled token selection, per-slot
    EOS and max-token detection, position advance, and the paged-KV
    writes all stay on device, and the host syncs once per macro-step to
    drain a [B, H] token block instead of once per token.  The scanned
    body advances ALL decoding slots together (per-slot position
    vector); slots still mid-prefill — or finishing mid-horizon — ride
    along masked out: zeroed page-table rows land their writes on the
    null page and their per-slot state reverts each scan step, so H
    fused steps are token- and KV-bit-identical to H single-step calls.
    Raise ``decode_horizon`` when decode is dispatch-bound (many small
    kernel launches per token — the regime every BENCH_serving cell
    measured pre-fusion); keep it at 1 when the page pool runs tight
    (horizon page reservations add transient pressure, though budgets
    shrink rather than preempt) or when a strict per-token SLO on the
    tokens right after TTFT matters — the first decode token of a
    request is only visible to the host after its whole macro-step;
  * finished slots are freed and re-usable; requests stop on
    ``max_new_tokens``, cache capacity, or their ``eos_token``;
  * eviction (paged engine): when the page pool runs dry mid-decode the
    latest-admitted request is preempted and requeued at the front; on
    re-admission it re-prefills over prompt + generated tokens, which is
    bit-identical to the uninterrupted decode because the chunked prefill
    matches the decode recurrence bit-for-bit.  A prefill that cannot
    grow its next chunk's pages (and has no later-admitted victim to
    evict) simply pauses at the chunk boundary, keeping its slot and
    pages, and resumes from ``pos`` next heartbeat;
  * standalone INT8 KV cache helpers (APSQ-style PO2 scales applied to
    whole cache tensors — ``quantize_kv``/``dequantize_kv``).

Integer serving (the calibrate -> export -> kernel-serving flow):

    params = calibrate_model(qat_params, cfg, batch)     # capture-based
    eng = ServingEngine.from_exported(params, cfg, backend="auto")
    eng.run([Request(uid=0, tokens=prompt)])

``from_exported`` exports every quantized linear to INT8 codes + PO2
shift exponents and the engine executes them through the ``repro.exec``
backend registry: ``backend="auto"`` (default) runs the real Pallas
APSQ kernel on TPU and the bit-identical jnp oracle elsewhere;
``backend="pallas"`` pins the kernel (interpret mode off-TPU — what CI
runs); ``backend="oracle"`` pins the reference semantics.  Greedy
decodes are token-for-token identical across backends.

Pallas launch geometry resolves per shape class through the block
autotuner (``repro.kernels.autotune``): decode steps (M=1) take the
single-row fast path, prefill chunks get large tiles, and stacked MoE
expert banks run as ONE fused grid over all experts.  Tuned winners in
the on-disk cache apply automatically; pass
``backend=PallasBackend(block_overrides={"decode_m1": BlockConfig(1,
512)})`` to pin blocks for a shape class explicitly.

The engine is host-driven (python around two jit'd functions) — the
launcher's ``serve.py`` runs it; the dry-run lowers ``serve_step`` from
``repro.launch.dryrun`` directly.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import (
    batch_state_axes,
    decode_horizon_paged,
    decode_step,
    decode_step_paged,
    forward_paged_chunk,
    init_decode_state,
    init_paged_decode_state,
    paged_state_axes,
)
from .paged_cache import NULL_PAGE, page_span


def _check_horizon(h) -> int:
    h = int(h)
    if h < 1 or (h & (h - 1)):
        raise ValueError(f"decode_horizon must be a power of two >= 1, "
                         f"got {h}")
    return h


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray            # prompt
    max_new_tokens: int = 32
    eos_token: int | None = None  # stop when this token is generated
    out: list = dataclasses.field(default_factory=list)
    done: bool = False

    def hit_eos(self) -> bool:
        return (self.eos_token is not None and len(self.out) > 0
                and self.out[-1] == self.eos_token)


# ---------------------------------------------------------------------------
# INT8 KV cache (beyond-paper, APSQ-style PO2 scales)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array):
    """Per-(batch, head) PO2-scale INT8 codes for KV cache pages.

    x: [B, S, H, hd].  Scales are powers of two so dequant is a shift —
    the same hardware argument the paper makes for PSUM scales (§II-B).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(1, 3), keepdims=True)
    exp = jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-8) / 127.0))
    scale = jnp.exp2(exp)
    codes = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def dequantize_kv(codes: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (codes.astype(jnp.float32) * scale).astype(dtype)


# Slot-axis trees live next to the state builders in ``models.model``
# (``batch_state_axes`` / ``paged_state_axes``); these aliases keep the
# engine's historical private names working for downstream code.
_batch_axes_tree = batch_state_axes


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 cache_len: int = 1024, prefill_chunk: int = 64,
                 decode_horizon: int = 1,
                 mesh=None, greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0, backend="auto", profile: bool = False):
        from repro.exec import get_backend
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prefill_chunk = prefill_chunk
        # Fused decode horizon (pow2): up to this many decode steps run
        # inside one jitted lax.scan per step() heartbeat, with a single
        # host sync draining the [B, H] token block.  Default 1 keeps the
        # reference engine on the classic one-token heartbeat; the paged
        # engine defaults to 8 (see PagedServingEngine).
        self.decode_horizon = _check_horizon(decode_horizon)
        self.profile = profile
        self.mesh = mesh
        self.greedy = greedy
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        # Integer execution backend for deployed params (repro.exec):
        # "auto" (default) serves the Pallas kernel on TPU and the jnp
        # oracle elsewhere; "pallas"/"oracle" (or an ExecBackend instance,
        # e.g. PallasBackend(interpret=True)) pin one explicitly.  Float /
        # fake-quant params ignore it.
        self.backend = get_backend(backend)

        self.state = init_decode_state(cfg, max_batch, cache_len)
        self.pos = np.zeros(max_batch, np.int32)      # next position per slot
        # Device-resident copy of ``pos``: decode advances it functionally
        # inside the jitted scan; the host mirror is only re-uploaded when
        # host code writes it (admission / prefill), not every step.
        self._pos_dev = None
        self._pos_dirty = True
        self.slots: list = [None] * max_batch
        self.reset_counters()
        self._decode = jax.jit(self._decode_impl, static_argnums=(0,))
        self._prefill = jax.jit(self._prefill_impl)

    def reset_counters(self) -> None:
        """Zero the dispatch/latency counters (benchmarks call this after
        warmup so compile time stays out of the measured window)."""
        self.decode_dispatches = 0     # jitted decode launches
        self.decode_device_steps = 0   # scan steps across those launches
        self.decode_seconds = 0.0      # wall time dispatch -> token drain
        self.horizon_hist: dict[int, int] = {}  # scan length -> launches

    @classmethod
    def from_exported(cls, params, cfg: ModelConfig, *, policy=None, **kw):
        """Serve the integer deployment path: export the calibrated QAT
        params (INT8 weight codes + PO2 shift exponents per layer, see
        ``repro.quant.export``) and run every projection GEMM through the
        ``kernels/apsq_matmul`` integer semantics inside decode.  The
        ``backend=`` knob picks the executor: ``auto`` (kernel on TPU,
        oracle elsewhere), ``pallas``, or ``oracle``."""
        from repro.quant.export import export_quantized
        deploy, _ = export_quantized(params, policy)
        return cls(deploy, cfg, **kw)

    # -- jitted bodies ------------------------------------------------------

    def _prefill_impl(self, params, state, tokens, slot, length):
        """Prefill one slot.  tokens: [1, Lpad] (bucket-padded); slot and
        length are traced scalars.  Steps the decode path token-by-token
        (identical cache layout to decode); state updates beyond ``length``
        are masked out so padding never pollutes recurrent state."""
        cfg = self.cfg
        fresh = init_decode_state(cfg, 1, self.cache_len)

        def body(carry, tok_pos):
            st, lg = carry
            tok, pos = tok_pos
            lg2, st2 = decode_step(params, cfg, st, tok[None, None], pos,
                                   mesh=self.mesh, backend=self.backend)
            valid = pos < length
            st = jax.tree.map(lambda a, b: jnp.where(valid, b, a), st, st2)
            lg = jnp.where(pos == length - 1, lg2[:, -1].astype(lg.dtype), lg)
            return (st, lg), ()

        lg0 = jnp.zeros((1, cfg.vocab), jnp.float32)
        (st, lg), _ = jax.lax.scan(
            body, (fresh, lg0),
            (tokens[0], jnp.arange(tokens.shape[1], dtype=jnp.int32)))
        axes = _batch_axes_tree(state, self.cfg.scan_layers)
        new_state = jax.tree.map(
            lambda full, s, ax: jax.lax.dynamic_update_slice_in_dim(
                full, s.astype(full.dtype), slot, axis=ax),
            state, st, axes)
        return new_state, lg

    def _decode_impl(self, h, params, state, tokens, pos, active, budget,
                     remaining, eos, rng):
        """``h`` fused decode steps for all slots in ONE ``lax.scan``.

        tokens [B, 1]; pos/budget/remaining/eos [B] int32; active [B]
        bool.  Sampling, EOS / token-budget detection and position
        advance all happen on device; the host drains the [B, h] token
        block once per call.  A slot that finishes (EOS or last token)
        mid-horizon keeps riding the batch with its position frozen and
        token 0 fed, exactly like an empty slot, so ``h`` fused steps
        emit the same tokens as ``h`` single-step calls.  ``eos`` is -1
        for slots without a stop token.  Returns (tok_block [B, h],
        emitted [B, h] prefix mask, state, pos, rng)."""
        cfg = self.cfg
        axes = _batch_axes_tree(state, cfg.scan_layers)
        temp = jnp.maximum(self.temperature, 1e-6)

        def one(st, tok, ps):
            # vmap strips the slot axis; reinsert a size-1 batch dim.
            st1 = jax.tree.map(lambda a, ax: jnp.expand_dims(a, ax),
                               st, axes)
            lg, st2 = decode_step(params, cfg, st1, tok[None], ps,
                                  mesh=self.mesh, backend=self.backend)
            st2 = jax.tree.map(lambda a, ax: jnp.squeeze(a, ax), st2, axes)
            return lg[0, -1], st2

        def body(carry, _):
            st, tok, ps, act, bud, rem, key = carry
            on = act & (bud > 0)
            logits, st2 = jax.vmap(
                one, in_axes=(axes, 0, 0), out_axes=(0, axes))(st, tok, ps)
            logits = logits / temp
            key, sub = jax.random.split(key)
            if self.greedy:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                nxt = jax.random.categorical(sub, logits,
                                             axis=-1).astype(jnp.int32)
            rem2 = jnp.where(on, rem - 1, rem)
            fin = on & ((nxt == eos) | (rem2 <= 0))
            tok2 = jnp.where(on, jnp.where(fin, 0, nxt), tok[:, 0])[:, None]
            carry2 = (st2, tok2, ps + on.astype(ps.dtype), act & ~fin,
                      bud - on.astype(bud.dtype), rem2, key)
            return carry2, (nxt, on)

        carry = (state, tokens, pos, active, budget, remaining, rng)
        (st, _, ps, _, _, _, key), (toks, ons) = jax.lax.scan(
            body, carry, None, length=h)
        return (jnp.moveaxis(toks, 0, 1), jnp.moveaxis(ons, 0, 1),
                st, ps, key)

    # -- host API -----------------------------------------------------------

    def add_request(self, req: Request) -> bool:
        """Prefill into a free slot; False if engine full."""
        try:
            slot = self.slots.index(None)
        except ValueError:
            return False
        L = int(len(req.tokens))
        pad = -L % self.prefill_chunk
        toks = np.pad(np.asarray(req.tokens, np.int32), (0, pad))[None]
        self.state, logits = self._prefill(
            self.params, self.state, jnp.asarray(toks),
            jnp.asarray(slot, jnp.int32), jnp.asarray(L, jnp.int32))
        self.slots[slot] = req
        self.pos[slot] = L
        self._pos_dirty = True
        req.out.append(int(jnp.argmax(logits[0])))
        if len(req.out) >= req.max_new_tokens or req.hit_eos():
            req.done = True  # finished on the prefill token; step() sweeps
        return True

    def step(self) -> list:
        """One decode macro-step (up to ``decode_horizon`` tokens per
        slot) for every active slot; returns finished requests."""
        finished = []
        for i, r in enumerate(self.slots):  # finished at admission (eos etc.)
            if r is not None and r.done:
                finished.append(r)
                self.slots[i] = None
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return finished
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        mask = np.zeros(B, np.bool_)
        bud = np.zeros(B, np.int32)
        rem = np.zeros(B, np.int32)
        eos = np.full(B, -1, np.int32)
        for i in active:
            r = self.slots[i]
            tokens[i, 0] = r.out[-1]
            mask[i] = True
            rem[i] = r.max_new_tokens - len(r.out)
            # Never scan past the cache: the last writable position is
            # cache_len - 2 (matching the old per-step pos bound check).
            bud[i] = min(self.decode_horizon,
                         self.cache_len - 1 - int(self.pos[i]))
            if r.eos_token is not None:
                eos[i] = r.eos_token
        # Snap the scan length to the largest useful step count (pow2 so
        # the jit compiles at most log2(decode_horizon)+1 variants).
        h = max(1, max(int(min(bud[i], rem[i])) for i in active))
        h = 1 << (h - 1).bit_length()
        if self._pos_dirty:
            self._pos_dev = jnp.asarray(self.pos)
            self._pos_dirty = False
        t0 = time.perf_counter()
        blk, em, self.state, self._pos_dev, self.rng = self._decode(
            h, self.params, self.state, jnp.asarray(tokens), self._pos_dev,
            jnp.asarray(mask), jnp.asarray(bud), jnp.asarray(rem),
            jnp.asarray(eos), self.rng)
        blk = np.asarray(blk)
        em = np.asarray(em)
        self.decode_seconds += time.perf_counter() - t0
        self.decode_dispatches += 1
        self.decode_device_steps += h
        self.horizon_hist[h] = self.horizon_hist.get(h, 0) + 1
        for i in active:
            r = self.slots[i]
            for t in range(h):
                if not em[i, t]:
                    break
                r.out.append(int(blk[i, t]))
                self.pos[i] += 1    # device pos advanced identically
            if (len(r.out) >= r.max_new_tokens
                    or self.pos[i] >= self.cache_len - 1
                    or r.hit_eos()):
                r.done = True
                finished.append(r)
                self.slots[i] = None
        return finished

    def run(self, requests: list) -> list:
        """Continuous batching until every request completes."""
        pending = list(requests)
        done: list = []
        while pending or any(s is not None for s in self.slots):
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            done.extend(self.step())
        return done


# ---------------------------------------------------------------------------
# Paged engine (continuous batching over the INT8 page pool)
# ---------------------------------------------------------------------------

_paged_axes_tree = paged_state_axes


class PagedServingEngine:
    """Continuous-batching engine over the paged INT8 KV cache.

    Same host API as ``ServingEngine`` (``Request`` in, ``step``/``run``
    out) but requests are queued through the ``repro.serving.scheduler``:
    admission waits for a slot + the FIRST prefill chunk's pages, prompts
    prefill chunk-by-chunk under a per-step token budget (interleaved
    with the decode batch), decode grows each slot's page list on demand,
    and a dry pool preempts the latest-admitted request (requeued at the
    front; resume re-prefills prompt + generated and is bit-identical).
    ``page_size`` doubles as the attention kernel's ``block_s`` tile.

    Knobs (see the module docstring for when to turn them):
      * ``prefill_chunk``        — max tokens per prefill forward; the
        chunk rides the m axis of every GEMM and the query-row axis of
        the attention kernel.  Raise it to cut TTFT on prompt-heavy
        loads; 1 degenerates to the old token-by-token prefill.
      * ``prefill_token_budget`` — prompt tokens spent per ``step()``
        across all mid-prefill slots (default ``prefill_chunk *
        max_batch``: every slot advances one chunk per heartbeat).
        Lower it to bound decode-step latency jitter at the cost of
        slower prompt-backlog draining (and so higher TTFT).
      * ``decode_horizon``       — fused decode steps per heartbeat
        (pow2, default 8): one jitted scan emits up to H tokens per slot
        with a single host sync.  Raise it when decode is
        dispatch-bound; 1 restores the classic per-token heartbeat
        (tight page pools, strict per-token SLO).  ``_ensure_capacity``
        pre-reserves each slot's pages over [pos, pos+H) and shrinks the
        slot's budget instead of preempting when the pool is tight.
      * ``profile``              — re-enable the per-prefill-chunk
        ``block_until_ready`` timing sync (fills ``prefill_seconds``);
        off by default so chunk dispatches overlap on device.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 page_size: int = 16, n_pages: int = 128,
                 max_pages_per_slot: int | None = None,
                 prefill_chunk: int = 16,
                 prefill_token_budget: int | None = None,
                 decode_horizon: int = 8,
                 mesh=None, greedy: bool = True,
                 temperature: float = 1.0, seed: int = 0, backend="auto",
                 wire: str = "int8", profile: bool = False):
        from repro.exec import get_backend
        from .scheduler import Scheduler
        if any(k == "local" for k in cfg.block_pattern) or cfg.softcap:
            raise NotImplementedError(
                "paged serving covers full-attention (+ recurrent) "
                "layers only — no sliding-window / softcap yet")
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.page_size = page_size
        self.prefill_chunk = max(int(prefill_chunk), 1)
        # Default budget: every slot can advance one full chunk per step.
        # A budget of one chunk TOTAL would serialize prompt admission
        # across slots and multiply TTFT by the mid-prefill slot count.
        self.prefill_token_budget = max(
            int(prefill_token_budget) if prefill_token_budget
            else self.prefill_chunk * max_batch, 1)
        # Fused decode horizon (pow2): up to this many decode steps per
        # heartbeat run inside ONE jitted lax.scan, with a single host
        # sync draining the [B, H] token block.  _ensure_capacity
        # pre-reserves each slot's pages over [pos, pos + H) and shrinks
        # the slot's budget (never preempting) when the pool is tight.
        # 1 degenerates to the classic one-token heartbeat.
        self.decode_horizon = _check_horizon(decode_horizon)
        # profile=True restores the per-prefill-chunk block_until_ready
        # timing sync (prefill_seconds) and decode timing; off (default),
        # prefill chunks of co-resident slots overlap their dispatch.
        self.profile = profile
        self.mesh = mesh
        self.greedy = greedy
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.backend = get_backend(backend)

        self.state = init_paged_decode_state(cfg, max_batch,
                                             page_size=page_size,
                                             n_pages=n_pages)
        # Multi-device integer serving: wrap the backend in the mesh-
        # parallel executor (repro.dist.tp plans the per-layer shard axis
        # from Algorithm-1 semantics), commit the exported code banks to
        # their shards, and shard the KV pools over kv-heads.  The plan's
        # analytic wire report lands on ``self.shard_plan`` for
        # ``benchmarks/dist_bench.py``.  ``wire="fp32"`` keeps identical
        # outputs but full-precision collectives (parity debugging).
        self.shard_plan = None
        if mesh is not None:
            from repro.dist.tp import shard_deployed, shard_paged_state
            from repro.exec import ShardedBackend
            if not isinstance(self.backend, ShardedBackend):
                self.backend = ShardedBackend(mesh=mesh, inner=self.backend,
                                              wire=wire)
            self.params, self.shard_plan = shard_deployed(params, mesh)
            self.state, attn_plans = shard_paged_state(self.state, cfg, mesh)
            self.shard_plan.update(attn_plans)
        self.sched = Scheduler(max_slots=max_batch, n_pages=n_pages,
                               page_size=page_size,
                               max_pages_per_slot=max_pages_per_slot,
                               admit_chunk=self.prefill_chunk)
        self.pos = np.zeros(max_batch, np.int32)      # next position per slot
        # Device-resident pos (see ServingEngine): the fused decode scan
        # advances positions functionally on device; the host mirror is
        # re-uploaded only after host writes (admission, prefill chunks).
        self._pos_dev = None
        self._pos_dirty = True
        # Mid-prefill bookkeeping: slot -> full resume stream (prompt +
        # pre-preemption output).  While a slot is here, ``pos[slot]`` is
        # its prefilled_len — the last completed chunk boundary.
        self._mid_prefill: dict[int, np.ndarray] = {}
        self.reset_counters()
        self._decode = jax.jit(self._decode_impl, static_argnums=(0,))
        self._prefill_chunk = jax.jit(self._prefill_chunk_impl)

    def reset_counters(self) -> None:
        """Zero dispatch/latency counters (benchmarks call this after the
        warmup request so compile time stays out of the window)."""
        self.prefill_tokens = 0      # prompt tokens pushed through chunks
        self.prefill_seconds = 0.0   # wall in chunk forwards (profile=True)
        self.prefill_dispatches = 0  # prefill chunk launches
        self.decode_dispatches = 0   # fused decode launches
        self.decode_device_steps = 0  # scan steps across those launches
        self.decode_seconds = 0.0    # wall time dispatch -> token drain
        self.horizon_hist: dict[int, int] = {}  # scan length -> launches

    @classmethod
    def from_exported(cls, params, cfg: ModelConfig, *, policy=None, **kw):
        """Integer serving end-to-end: INT8 weights through the APSQ GEMM
        kernel *and* INT8 KV pages through the flash-decode kernel."""
        from repro.quant.export import export_quantized
        deploy, _ = export_quantized(params, policy)
        return cls(deploy, cfg, **kw)

    # -- jitted bodies ------------------------------------------------------

    def _prefill_chunk_impl(self, params, state, tokens, slot, start,
                            table_row):
        """Prefill ONE chunk of one slot against the shared page pools.

        tokens [1, C] (every token valid — chunk sizes are exact);
        ``slot``/``start`` traced scalars; ``table_row`` [1, n_max].  One
        batched ``forward_paged_chunk`` whose paged-cache writes replay
        the per-token bump-rescale recurrence, so the pools and running
        exponents end bit-identical to C single-token decode steps — a
        resumed (preempted) request recomputes exactly the cache it
        lost.  ``start == 0`` (first chunk) resets the slot's per-slot
        leaves (exponents, recurrent states) left by a prior occupant."""
        cfg = self.cfg
        axes = _paged_axes_tree(state, cfg.scan_layers)
        fresh = init_paged_decode_state(cfg, 1, page_size=self.page_size,
                                        n_pages=1)  # pools unused
        sub = jax.tree.map(
            lambda full, fr, ax: full if ax == -1 else jnp.where(
                start == 0, fr,
                jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=ax)),
            state, fresh, axes)
        lg, st = forward_paged_chunk(
            params, cfg, sub, tokens,
            jnp.full((1,), start, jnp.int32), table_row,
            mesh=self.mesh, backend=self.backend)
        new_state = jax.tree.map(
            lambda full, s, ax: s if ax == -1
            else jax.lax.dynamic_update_slice_in_dim(
                full, s.astype(full.dtype), slot, axis=ax),
            state, st, axes)
        return new_state, lg[:, -1]

    def _decode_impl(self, h, params, state, tokens, pos, table, active,
                     budget, remaining, eos, rng):
        """``h`` fused decode steps for all slots in ONE ``lax.scan`` —
        the scanned body is ``decode_step_paged`` with the PR-8 masking
        applied per scan step: slots that are inactive (empty or
        mid-prefill), finished mid-horizon (EOS / last token), or out of
        page budget carry all-null table rows (their garbage writes land
        on the masked null page) and have their per-slot leaves — running
        exponents, recurrent states — reverted, so riding along in the
        batch cannot disturb a slot that is not decoding.  See
        ``models.model.decode_horizon_paged`` for the contract."""
        return decode_horizon_paged(
            params, self.cfg, state, tokens, pos, table,
            horizon=h, active=active, budget=budget, remaining=remaining,
            eos=eos, greedy=self.greedy, temperature=self.temperature,
            rng=rng, mesh=self.mesh, backend=self.backend)

    # -- host API -----------------------------------------------------------

    def add_request(self, req: Request) -> bool:
        """Queue a request (admission happens inside ``step``)."""
        self.sched.submit(req)
        return True

    def _admit(self) -> None:
        """Admit queued requests while a slot + the FIRST chunk's pages
        are free.  Admission only books the slot; the prompt itself runs
        chunk-by-chunk in ``_prefill_step`` (later pages grow per chunk)."""
        while True:
            got = self.sched.admit_next()
            if got is None:
                return
            slot, req, resume = got
            self._mid_prefill[slot] = np.asarray(resume, np.int32)
            self.pos[slot] = 0
            self._pos_dirty = True

    def _preempt(self, slot: int) -> None:
        """Preempt a slot (decoding or mid-prefill), releasing its pages.
        Its request requeues at the front; a mid-prefill victim loses its
        chunk progress and re-prefills from scratch on re-admission."""
        self._mid_prefill.pop(slot, None)
        self.sched.preempt(slot)

    def _grow_range(self, slot: int, start: int, end: int) -> bool:
        """Ensure pages exist for positions [start, end).  A dry pool
        evicts only slots admitted LATER than ``slot`` (so prefill never
        steals from older work); False means pause at this chunk
        boundary — the slot keeps its pages and resumes next step."""
        for p in page_span(start, end, self.page_size):
            while not self.sched.grow(slot, p):
                victim = self.sched.evict_candidate(exclude=slot)
                if victim is None or (self.sched._admitted_at[victim]
                                      <= self.sched._admitted_at[slot]):
                    return False
                self._preempt(victim)
        return True

    def _prefill_step(self) -> None:
        """Advance mid-prefill slots, oldest first, spending at most
        ``prefill_token_budget`` prompt tokens.  Chunk sizes are powers
        of two <= ``prefill_chunk`` (so every chunk is fully valid — no
        pad masking — and the chunk body compiles for at most
        log2(prefill_chunk)+1 shapes).  The final chunk's logits produce
        the request's first output token, exactly like a decode step."""
        budget = self.prefill_token_budget
        order = sorted(self._mid_prefill,
                       key=lambda s: self.sched._admitted_at[s])
        for s in order:
            if s not in self._mid_prefill:            # evicted by a grow
                continue
            resume = self._mid_prefill[s]
            while budget > 0 and int(self.pos[s]) < len(resume):
                done = int(self.pos[s])
                c = min(self.prefill_chunk, len(resume) - done, budget)
                c = 1 << (c.bit_length() - 1)         # pow2 chunk sizes
                if not self._grow_range(s, done, done + c):
                    return                            # pool dry: pause
                t0 = time.perf_counter() if self.profile else 0.0
                self.state, logits = self._prefill_chunk(
                    self.params, self.state,
                    jnp.asarray(resume[done:done + c][None]),
                    jnp.asarray(s, jnp.int32), jnp.asarray(done, jnp.int32),
                    jnp.asarray(self.sched.table[s:s + 1]))
                if self.profile:
                    # Timing sync only under profile=: the default path
                    # leaves chunk dispatches of co-resident slots free
                    # to overlap on device.
                    logits.block_until_ready()
                    self.prefill_seconds += time.perf_counter() - t0
                self.prefill_dispatches += 1
                self.prefill_tokens += c
                self.pos[s] = done + c
                self._pos_dirty = True
                budget -= c
                if done + c == len(resume):           # prompt fully cached
                    req = self.sched.slots[s]
                    req.out.append(int(jnp.argmax(logits[0])))
                    del self._mid_prefill[s]
                    if len(req.out) >= req.max_new_tokens or req.hit_eos():
                        req.done = True               # swept by step()
            if budget <= 0:
                return

    def _ensure_capacity(self, horizon: int = 1):
        """Grow each decoding slot's pages for its next write plus — pool
        permitting — the rest of its decode horizon.

        The FIRST page (the next write position) keeps the old guarantee:
        a dry pool preempts latest-admitted requests until it fits.  The
        horizon extension over ``[pos + 1, pos + horizon)`` is
        opportunistic (``Scheduler.grow_span`` never evicts): when the
        pool is tight the slot's macro-step budget simply shrinks — down
        to the single guaranteed token — instead of preempting
        co-resident work.  Positions past a slot's budget stay masked in
        the scan, so partially covered horizons are safe.

        Returns ``(finished, budgets)``: requests finished by running out
        of page budget, and per-slot device-step budgets [max_batch]
        int32 (0 for empty / mid-prefill slots, else >= 1)."""
        finished = []
        budgets = np.zeros(self.max_batch, np.int32)
        order = sorted(
            (s for s, r in enumerate(self.sched.slots)
             if r is not None and s not in self._mid_prefill),
            key=lambda s: self.sched._admitted_at[s])
        for s in order:                               # oldest first
            if self.sched.slots[s] is None:           # evicted below
                continue
            pos = int(self.pos[s])
            if pos >= self.sched.capacity_tokens:
                r = self.sched.finish(s)              # page budget exhausted
                r.done = True
                finished.append(r)
                continue
            guaranteed = True
            while not self.sched.grow(s, pos):
                victim = self.sched.evict_candidate()
                if victim is None or victim == s:
                    if victim == s:                   # newest = itself
                        self._preempt(s)
                        guaranteed = False
                        break
                    raise RuntimeError("page pool dry with no evictable slot")
                self._preempt(victim)
            if not guaranteed:
                continue
            r = self.sched.slots[s]
            want = max(1, min(horizon, self.sched.capacity_tokens - pos,
                              r.max_new_tokens - len(r.out)))
            # End of the guaranteed page, then extend page by page.
            covered = min(pos + want,
                          (pos // self.page_size + 1) * self.page_size)
            if pos + want > covered:
                covered = min(pos + want, covered + self.sched.grow_span(
                    s, covered, pos + want))
            budgets[s] = covered - pos
        return finished, budgets

    def _admit_and_prefill(self) -> list:
        """Admit + prefill + sweep requests finished on their prefill
        token.  Runs at the top of every step AND again after the decode
        sweep, so a slot freed by a finishing stream starts (and usually
        completes) its successor's prefill in the same heartbeat instead
        of idling until the next one — under slot contention that saves
        one full decode step of TTFT per queued request."""
        self._admit()
        self._prefill_step()
        finished = []
        for s, r in enumerate(self.sched.slots):
            if r is not None and r.done:              # done on prefill token
                finished.append(self.sched.finish(s))
        return finished

    def step(self) -> list:
        """One continuous-batching heartbeat: admit (slot + first-chunk
        pages), spend the prefill token budget on mid-prefill slots,
        sweep requests finished on their prefill token, ensure decode
        pages over each slot's horizon (evicting only for the first
        token if dry), then ONE fused decode macro-step — up to
        ``decode_horizon`` tokens per decoding slot inside a single
        jitted scan (mid-prefill slots ride along inert) — drain the
        [B, H] token block, and finally re-admit into any slots the
        decode sweep freed."""
        finished = self._admit_and_prefill()
        fin_cap, budgets = self._ensure_capacity(self.decode_horizon)
        finished.extend(fin_cap)
        active = [s for s, r in enumerate(self.sched.slots)
                  if r is not None and s not in self._mid_prefill]
        if not active:
            return finished
        B = self.max_batch
        tokens = np.zeros((B, 1), np.int32)
        mask = np.zeros(B, np.bool_)
        rem = np.zeros(B, np.int32)
        eos = np.full(B, -1, np.int32)
        for s in active:
            r = self.sched.slots[s]
            tokens[s, 0] = r.out[-1]
            mask[s] = True
            rem[s] = r.max_new_tokens - len(r.out)
            if r.eos_token is not None:
                eos[s] = r.eos_token
        # Zero the table rows of non-decoding slots: their (garbage)
        # writes land on the null page instead of live cache pages.
        table = np.where(mask[:, None], self.sched.table, NULL_PAGE)
        # Scan just long enough for the biggest per-slot budget, snapped
        # to pow2 (at most log2(decode_horizon)+1 compiled variants).
        h = max(1, max(int(budgets[s]) for s in active))
        h = 1 << (h - 1).bit_length()
        if self._pos_dirty:
            self._pos_dev = jnp.asarray(self.pos)
            self._pos_dirty = False
        t0 = time.perf_counter()
        blk, em, self.state, self._pos_dev, self.rng = self._decode(
            h, self.params, self.state, jnp.asarray(tokens), self._pos_dev,
            jnp.asarray(table), jnp.asarray(mask), jnp.asarray(budgets),
            jnp.asarray(rem), jnp.asarray(eos), self.rng)
        blk = np.asarray(blk)     # the macro-step's single host sync
        em = np.asarray(em)
        self.decode_seconds += time.perf_counter() - t0
        self.decode_dispatches += 1
        self.decode_device_steps += h
        self.horizon_hist[h] = self.horizon_hist.get(h, 0) + 1
        for s in active:
            r = self.sched.slots[s]
            for t in range(h):
                if not em[s, t]:
                    break
                r.out.append(int(blk[s, t]))
                self.pos[s] += 1  # device pos advanced identically
            if len(r.out) >= r.max_new_tokens or r.hit_eos():
                r.done = True
                finished.append(self.sched.finish(s))
        if self.sched.waiting:                        # refill freed slots now
            finished.extend(self._admit_and_prefill())
        return finished

    def run(self, requests: list) -> list:
        """Continuous batching until every request completes."""
        for r in requests:
            self.sched.submit(r)
        done: list = []
        while self.sched.waiting or any(
                s is not None for s in self.sched.slots):
            done.extend(self.step())
            self.sched.assert_invariants()
        return done
