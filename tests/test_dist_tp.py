"""Tensor/expert-parallel integer serving (``repro.dist.tp``).

Three levels, all bit-exact by construction (PO2 grids, integer
arithmetic):

  * plan level — ``plan_gemm`` picks the shard axis Algorithm-1 allows
    (K by whole PSUM tiles for PSQ/W8A8, N for APSQ's sequential chain)
    with divisibility fallbacks;
  * GEMM level — ``ShardedBackend`` over a 2/8-device host mesh returns
    the same integers as the single-device oracle for every mode x
    exponent layout x wire flag;
  * engine level — ``PagedServingEngine.from_exported(mesh=...)`` greedy
    decode is token-identical (and KV pool/exponent identical) to the
    single-device engine, for dense, MoE expert-parallel and per-column
    exponent exports, on both wire modes.

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(tests/conftest.py sets it).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QuantConfig
from repro.dist.tp import (GemmPlan, plan_gemm, shard_deployed,
                           wire_report)
from repro.exec import ShardedBackend, get_backend
from repro.kernels.apsq_matmul.ref import choose_exps
from repro.launch.mesh import make_smoke_mesh
from repro.models.config import ModelConfig
from repro.models.model import init_lm, lm_specs
from repro.quant import calibrate_model, export_quantized
from repro.serving import PagedServingEngine, Request

needs2 = pytest.mark.skipif(len(jax.devices()) < 2, reason="needs 2 devices")
needs8 = pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")


# ---------------------------------------------------------------------------
# Plan level
# ---------------------------------------------------------------------------

def test_plan_gemm_axis_by_mode():
    # PSQ: K by whole PSUM tiles whenever n_p divides
    assert plan_gemm(k=32, n=16, n_p=4, gs=4, d=2) == GemmPlan("k", "psq", 2)
    # gs >= n_p EXECUTES as psq even if declared apsq
    assert plan_gemm(k=32, n=16, n_p=4, gs=8, d=2).mode == "psq"
    # APSQ: sequential chain along K -> column-parallel
    assert plan_gemm(k=32, n=16, n_p=4, gs=2, d=2) == GemmPlan("n", "apsq", 2)
    # W8A8: exact int32 psum over K spans
    assert plan_gemm(k=32, n=16, n_p=None, gs=1, d=2) == \
        GemmPlan("k", "w8a8", 2)


def test_plan_gemm_fallbacks():
    # psq with n_p % d != 0 -> N; N % d != 0 too -> replicate
    assert plan_gemm(k=32, n=16, n_p=3, gs=3, d=2).axis == "n"
    assert plan_gemm(k=32, n=15, n_p=3, gs=3, d=2).axis == "replicate"
    # w8a8 ragged K -> N
    assert plan_gemm(k=33, n=16, n_p=None, gs=1, d=2).axis == "n"
    # single device: always replicate, never sharded
    p = plan_gemm(k=32, n=16, n_p=4, gs=4, d=1)
    assert p.axis == "replicate" and not p.sharded


# ---------------------------------------------------------------------------
# Mesh construction (launch.mesh honoring requested shapes)
# ---------------------------------------------------------------------------

def test_smoke_mesh_default_spans_all_devices():
    mesh = make_smoke_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape["model"] == len(jax.devices())


@needs2
def test_smoke_mesh_honors_requested_shape():
    mesh = make_smoke_mesh((1, 2))
    assert dict(mesh.shape) == {"data": 1, "model": 2}


@needs8
def test_smoke_mesh_multi_pod_shape():
    mesh = make_smoke_mesh((2, 2, 2), ("pod", "data", "model"))
    assert dict(mesh.shape) == {"pod": 2, "data": 2, "model": 2}


def test_smoke_mesh_rejects_bad_requests():
    with pytest.raises(ValueError, match="rank mismatch"):
        make_smoke_mesh((2, 2, 2))           # 3 dims, 2 default axes
    with pytest.raises(ValueError, match="devices"):
        make_smoke_mesh((1, 4096))


# ---------------------------------------------------------------------------
# GEMM level: sharded == oracle, every mode/layout/wire
# ---------------------------------------------------------------------------

def _gemm_case(k, n, n_p, gs, per_col, seed=0):
    key = jax.random.PRNGKey(seed)
    x = jax.random.randint(key, (4, k), -128, 128, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -128, 128,
                           jnp.int8)
    exps = None
    if n_p is not None:
        exps = choose_exps(x, w, n_p=n_p, gs=gs)
        if per_col:
            exps = jnp.broadcast_to(exps[:, None], (n_p, n))
    return x, w, exps


GEMM_CASES = [
    # (tag,        k,  n, n_p, gs, per_col)
    ("apsq",       32, 16, 4, 2, False),
    ("apsq-pcol",  32, 16, 4, 2, True),
    ("psq",        32, 16, 4, 4, False),
    ("psq-pcol",   32, 16, 4, 4, True),
    ("psq-ragged", 36, 16, 4, 4, False),   # K % n_p != 0 zero-pad tail
    ("w8a8",       32, 16, None, 1, False),
]


@needs2
@pytest.mark.parametrize("tag,k,n,n_p,gs,per_col", GEMM_CASES,
                         ids=[c[0] for c in GEMM_CASES])
@pytest.mark.parametrize("wire", ["int8", "fp32"])
def test_sharded_gemm_matches_oracle(tag, k, n, n_p, gs, per_col, wire):
    x, w, exps = _gemm_case(k, n, n_p, gs, per_col)
    ref = get_backend("oracle").int_gemm(x, w, exps, gs=gs)
    mesh = make_smoke_mesh((1, 2))
    be = ShardedBackend(mesh=mesh, inner="oracle", wire=wire)
    y = be.int_gemm(x, w, exps, gs=gs)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


@needs8
@pytest.mark.parametrize("tag,k,n,n_p,gs,per_col",
                         [GEMM_CASES[0], GEMM_CASES[2], GEMM_CASES[5]],
                         ids=["apsq", "psq", "w8a8"])
def test_sharded_gemm_matches_oracle_8dev(tag, k, n, n_p, gs, per_col):
    x, w, exps = _gemm_case(k, n, n_p, gs, per_col)
    ref = get_backend("oracle").int_gemm(x, w, exps, gs=gs)
    # n_p=4 < 8 devices: psq K-shard misses divisibility -> N fallback;
    # parity must hold through the fallback chain too.
    be = ShardedBackend(mesh=make_smoke_mesh((1, 8)), inner="oracle")
    np.testing.assert_array_equal(np.asarray(be.int_gemm(x, w, exps, gs=gs)),
                                  np.asarray(ref))


@needs8
def test_sharded_gemm_on_multi_pod_mesh():
    """Full-manual over all axes: axis_index in the bodies must not trip
    GSPMD's PartitionId limitation when idle pod/data axes exist."""
    x, w, exps = _gemm_case(32, 16, 4, 2, True)  # per-col exercises idx
    ref = get_backend("oracle").int_gemm(x, w, exps, gs=2)
    mesh = make_smoke_mesh((2, 2, 2), ("pod", "data", "model"))
    be = ShardedBackend(mesh=mesh, inner="oracle")
    np.testing.assert_array_equal(np.asarray(be.int_gemm(x, w, exps, gs=2)),
                                  np.asarray(ref))


@needs2
@pytest.mark.parametrize("wire", ["int8", "fp32"])
def test_sharded_expert_gemm_matches_oracle(wire):
    key = jax.random.PRNGKey(3)
    E, M, K, N, n_p, gs = 4, 2, 32, 16, 4, 2
    x = jax.random.randint(key, (E, M, K), -128, 128, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (E, K, N),
                           -128, 128, jnp.int8)
    exps = jnp.stack([choose_exps(x[e], w[e], n_p=n_p, gs=gs)
                      for e in range(E)])
    ref = get_backend("oracle").int_expert_gemm(x, w, exps, gs=gs)
    be = ShardedBackend(mesh=make_smoke_mesh((1, 2)), inner="oracle",
                        wire=wire)
    np.testing.assert_array_equal(
        np.asarray(be.int_expert_gemm(x, w, exps, gs=gs)), np.asarray(ref))


def test_sharded_backend_rejects_bad_wire():
    with pytest.raises(ValueError, match="wire"):
        ShardedBackend(wire="int7")


def test_sharded_backend_meshless_delegates():
    # the registered instance has no mesh: pure delegation to inner
    x, w, exps = _gemm_case(32, 16, 4, 2, False)
    y = get_backend("sharded").int_gemm(x, w, exps, gs=2)
    ref = get_backend("oracle").int_gemm(x, w, exps, gs=2)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))


# ---------------------------------------------------------------------------
# Placement + spec tooling on exported trees
# ---------------------------------------------------------------------------

def _cfg(**kw):
    base = dict(name="tp", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, dtype="float32",
                scan_layers=False, quant=QuantConfig.apsq(gs=2, n_p=4))
    base.update(kw)
    return ModelConfig(**base)


def _exported(cfg, seed=0):
    p = init_lm(jax.random.PRNGKey(seed), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, 16), 0,
                             cfg.vocab)
    return export_quantized(calibrate_model(p, cfg, {"tokens": tok}))[0]


@needs2
def test_shard_deployed_places_and_reports():
    cfg = _cfg()
    dep = _exported(cfg)
    mesh = make_smoke_mesh((1, 2))
    placed, plans = shard_deployed(dep, mesh)
    # same tree structure, arrays committed to the mesh
    assert jax.tree.structure(placed) == jax.tree.structure(dep)
    assert plans, "expected a non-empty plan dict"
    assert any(pl.axis != "replicate" for pl in plans.values())
    # placement matches plan_gemm on every planned GEMM
    for name, pl in plans.items():
        if pl.kind == "attn":
            continue
        assert pl.axis == plan_gemm(k=pl.k, n=pl.n, n_p=pl.n_p, gs=pl.gs,
                                    d=pl.d).axis, name
    # the analytic report aggregates and the PSUM-mode combines switch
    wr = wire_report(plans, m=1)
    assert wr["switchable"]["ratio"] is not None
    assert wr["switchable"]["ratio"] >= 3.5
    # values are untouched by placement (device_put only)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), dep, placed)


def test_tree_specs_handles_deployed_tree():
    from repro.core import DeployedQuantState
    from repro.dist import tree_specs
    cfg = _cfg(scan_layers=True)
    dep = _exported(cfg)
    mesh = make_smoke_mesh()
    specs = tree_specs(lm_specs(cfg), dep, mesh)
    # params structure preserved (jit in_shardings ready)
    assert jax.tree.structure(jax.tree.map(lambda _: 0, specs)) == \
        jax.tree.structure(jax.tree.map(lambda _: 0, dep))

    found = []

    def walk(sp, dq):
        if isinstance(dq, DeployedQuantState):
            found.append(sp)
            assert sp.ax_exp == jax.sharding.PartitionSpec()
            assert sp.aw_exp == jax.sharding.PartitionSpec()
            assert isinstance(sp.w_codes, jax.sharding.PartitionSpec)
        elif isinstance(dq, dict):
            for k in dq:
                walk(sp[k], dq[k])

    walk(specs, dep)
    assert found, "no DeployedQuantState leaves visited"


# ---------------------------------------------------------------------------
# Engine level: the acceptance gate
# ---------------------------------------------------------------------------

def _decode(params, cfg, mesh=None, wire="int8", backend="oracle"):
    eng = PagedServingEngine.from_exported(
        params, cfg, max_batch=2, page_size=8, n_pages=16, prefill_chunk=8,
        backend=backend, mesh=mesh, wire=wire)
    prompts = [((np.arange(n) * 7 + s * 13) % cfg.vocab).astype(np.int32)
               for n, s in ((5, 0), (9, 1))]
    done = eng.run([Request(uid=i, tokens=p, max_new_tokens=5)
                    for i, p in enumerate(prompts)])
    outs = tuple(tuple(r.out) for r in sorted(done, key=lambda r: r.uid))
    return outs, jax.tree.map(np.asarray, jax.device_get(eng.state))


ENGINE_CASES = {
    # per_channel_w=True (default) exports per-column [n_p, N] exponents
    "dense-percol": dict(),
    "dense": dict(quant=QuantConfig(
        enabled=True, per_channel_w=False,
        psum=QuantConfig.apsq(gs=2, n_p=4).psum)),
    "moe-ep": dict(mlp="moe", n_experts=4, top_k=2),
}


@needs2
@pytest.mark.parametrize("case", list(ENGINE_CASES))
def test_engine_sharded_decode_matches_single_device(case):
    """ISSUE acceptance: greedy decode through the sharded engine is
    token-identical AND KV-pool/exponent identical to single-device, on
    both wire modes."""
    cfg = _cfg(**ENGINE_CASES[case])
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    ref_outs, ref_state = _decode(p2, cfg)
    for wire in ("int8", "fp32"):
        outs, state = _decode(p2, cfg, mesh=make_smoke_mesh((1, 2)),
                              wire=wire)
        assert outs == ref_outs, (case, wire)
        jax.tree.map(np.testing.assert_array_equal, ref_state, state)
    if case == "dense-percol":
        # The acceptance bar is parity with the single-device *pallas*
        # backend: pin oracle == pallas here, and run the sharded engine
        # with the pallas kernel as the per-shard inner once.
        pal_outs, pal_state = _decode(p2, cfg, backend="pallas")
        assert pal_outs == ref_outs
        jax.tree.map(np.testing.assert_array_equal, ref_state, pal_state)
        outs, state = _decode(p2, cfg, mesh=make_smoke_mesh((1, 2)),
                              backend="pallas")
        assert outs == ref_outs
        jax.tree.map(np.testing.assert_array_equal, ref_state, state)


@needs8
def test_engine_sharded_decode_matches_single_device_8dev():
    cfg = _cfg()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    p2 = calibrate_model(p, cfg, {"tokens": tok})
    ref_outs, ref_state = _decode(p2, cfg)
    outs, state = _decode(p2, cfg, mesh=make_smoke_mesh((1, 8)))
    assert outs == ref_outs
    jax.tree.map(np.testing.assert_array_equal, ref_state, state)


# ---------------------------------------------------------------------------
# Gradient compression: bits routing (satellite of the same wire story)
# ---------------------------------------------------------------------------

def test_int4_pack_roundtrip_exact():
    from repro.dist import pack_int4, unpack_int4
    codes = jnp.arange(-8, 8, dtype=jnp.int8).reshape(4, 4)
    packed = pack_int4(codes)
    assert packed.size == 8                 # two codes per byte
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(packed, codes.size, codes.shape)),
        np.asarray(codes))
    odd = jnp.asarray([-8, 7, 3], jnp.int8)  # odd length pads
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(odd), 3, (3,))), np.asarray(odd))


def test_compress_tree_psum_rejects_unknown_bits():
    from repro.dist import compress_tree_psum
    with pytest.raises(ValueError, match="bits"):
        compress_tree_psum({"g": jnp.ones(4)}, "pod", bits=3)


@needs2
@pytest.mark.parametrize("bits", [4, 8])
def test_compress_tree_psum_wire_accounting(bits):
    from repro.dist import compress_tree_psum
    from repro.dist.sharding import shard_map
    mesh = make_smoke_mesh((2, 1), ("pod", "data"))
    g = {"a": jnp.linspace(-1, 1, 64).reshape(8, 8),
         "b": jnp.linspace(-2, 2, 10)}
    info_box = {}

    def body(tree):
        out, info = compress_tree_psum(tree, "pod", bits=bits)
        info_box.update(info)
        return out

    from jax.sharding import PartitionSpec as P
    f = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                  axis_names={"pod"})
    out = jax.jit(f)(g)
    assert info_box["bits"] == bits
    # 74 elements: 8-bit -> 74 code bytes, 4-bit -> 37; +4B scale per leaf
    assert info_box["wire_bytes"] == (74 * bits + 7) // 8 + 8
    assert info_box["fp32_bytes"] == 4 * 74
    # identical grads on every pod replica -> mean of quantized == quantized;
    # 4-bit is coarser but still finite and close
    for k in g:
        err = float(jnp.max(jnp.abs(out[k] - g[k])))
        assert err <= (2.0 if bits == 4 else 0.5) * 2 / (2 ** (bits - 1) - 1)
