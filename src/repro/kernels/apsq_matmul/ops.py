"""Jit'd public wrappers around the APSQ Pallas kernels.

Handles padding to block multiples, interpret-mode fallback on CPU, operand
quantization from float, and rescaling of the integer result back to float.

Block sizes: every entry point takes ``block_m``/``block_n`` (and, where
2-D exponents are in play, ``exp_layout``).  Left as ``None`` they resolve
through ``repro.kernels.autotune.get_block_config`` — the per-shape-class
cache of tuned winners with a static heuristic fallback — so callers get
shape-appropriate launch geometry (m=1 decode fast path, large prefill
tiles, fused expert blocks) without naming blocks anywhere.  Explicit
values are respected, clamped to the padded operand dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import autotune
from . import ref
from .kernel import (
    apsq_expert_matmul_kernel,
    apsq_matmul_kernel,
    apsq_matmul_m1_kernel,
    baseline_expert_matmul_kernel,
    baseline_matmul_kernel,
)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _resolve_blocks(m, k, n, *, n_p, gs, block_m, block_n, exp_layout=None,
                    expert=False):
    """Fill in unset block params from the autotune table, then clamp to
    the padded operand dims (a block never exceeds what one tile covers)."""
    cfg = None
    if block_m is None or block_n is None or exp_layout is None:
        cfg = autotune.get_block_config(m, k, n, n_p=n_p, gs=gs,
                                        expert=expert)
    bm = cfg.block_m if block_m is None else block_m
    bn = cfg.block_n if block_n is None else block_n
    layout = (cfg.exp_layout if cfg is not None else "blocked") \
        if exp_layout is None else exp_layout
    if bm != 1:
        bm = max(1, min(bm, _round_up(m, 8)))
    if n < 128:  # unit-test shapes: one lane tile, no column padding
        bn = n
    else:
        bn = max(128, min(bn, _round_up(n, 128)))
    return bm, bn, layout


def apsq_matmul_int8(
    x_codes: jax.Array,
    w_codes: jax.Array,
    exps: jax.Array,
    *,
    gs: int,
    block_m: int | None = None,
    block_n: int | None = None,
    exp_layout: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """INT8 GEMM with Algorithm-1 PSUM handling; returns INT32 [M, N].

    ``n_p`` is taken from ``exps.shape[0]``.  Ragged ``K % n_p != 0`` is
    handled by zero-padding K into a remainder PSUM group (zero codes
    contribute nothing to the final tile's partial sum).  ``exps`` is
    [n_p] (per-tensor) or [n_p, N] (per-channel weight scales).

    M == 1 with an unpinned ``block_m`` takes the decode fast path
    (``apsq_matmul_m1_kernel``: one grid row over N, the whole K
    reduction unrolled in-register) — bit-identical to the generic grid.
    """
    if interpret is None:
        interpret = _default_interpret()
    m, k = x_codes.shape
    n = w_codes.shape[1]
    n_p = int(exps.shape[0])
    bm, bn, layout = _resolve_blocks(m, k, n, n_p=n_p, gs=gs,
                                     block_m=block_m, block_n=block_n,
                                     exp_layout=exp_layout)
    x_codes, w_codes = ref.pad_ragged_k(x_codes, w_codes, n_p)
    exps = exps.astype(jnp.int32)
    if exps.ndim == 2:  # pad the column axis alongside w (exponent 0 is id)
        exps = _pad_to(exps, 1, bn)
    if m == 1 and bm == 1:
        wp = _pad_to(w_codes, 1, bn)
        out = apsq_matmul_m1_kernel(
            x_codes, wp, exps, n_p=n_p, gs=int(gs), block_n=bn,
            interpret=interpret)
        return out[:, :n]
    bm = max(bm, 8)  # the generic grid pads rows to sublane multiples
    xp = _pad_to(x_codes, bm, 1)
    wp = _pad_to(w_codes, 1, bn)
    out = apsq_matmul_kernel(
        xp, wp, exps,
        n_p=n_p, gs=int(gs), block_m=bm, block_n=bn, exp_layout=layout,
        interpret=interpret,
    )
    return out[:m, :n]


def apsq_expert_matmul_int8(
    x_codes: jax.Array,
    w_codes: jax.Array,
    exps: jax.Array,
    *,
    gs: int,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused expert-bank GEMM: [E, M, K] @ [E, K, N] -> [E, M, N] INT32.

    ONE ``pallas_call`` serves all E experts (the expert axis is grid
    dimension 0).  ``exps`` carries per-expert exponent banks: [E, n_p]
    (per-tensor) or [E, n_p, N] (per-channel).  Ragged ``K % n_p`` gets
    the same zero-contribution remainder group as the single-expert path.
    """
    if interpret is None:
        interpret = _default_interpret()
    n_e, m, k = x_codes.shape
    n = w_codes.shape[2]
    n_p = int(exps.shape[1])
    bm, bn, _ = _resolve_blocks(m, k, n, n_p=n_p, gs=gs, block_m=block_m,
                                block_n=block_n, exp_layout="blocked",
                                expert=True)
    bm = max(bm, min(8, _round_up(m, 8)))  # expert grid has no m=1 path
    pad_k = (-k) % n_p
    if pad_k:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, 0), (0, pad_k)))
        w_codes = jnp.pad(w_codes, ((0, 0), (0, pad_k), (0, 0)))
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    if pad_m:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, pad_m), (0, 0)))
    if pad_n:
        w_codes = jnp.pad(w_codes, ((0, 0), (0, 0), (0, pad_n)))
    exps = exps.astype(jnp.int32)
    if exps.ndim == 3 and pad_n:
        exps = jnp.pad(exps, ((0, 0), (0, 0), (0, pad_n)))
    out = apsq_expert_matmul_kernel(
        x_codes, w_codes, exps,
        n_p=n_p, gs=int(gs), block_m=bm, block_n=bn, interpret=interpret,
    )
    return out[:, :m, :n]


def baseline_expert_matmul_int8(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    n_p: int = 1,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused INT32-accumulator W8A8 expert GEMM; returns INT32 [E, M, N]."""
    if interpret is None:
        interpret = _default_interpret()
    n_e, m, k = x_codes.shape
    n = w_codes.shape[2]
    bm, bn, _ = _resolve_blocks(m, k, n, n_p=n_p, gs=1, block_m=block_m,
                                block_n=block_n, exp_layout="blocked",
                                expert=True)
    bm = max(bm, min(8, _round_up(m, 8)))
    pad_k = (-k) % n_p
    if pad_k:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, 0), (0, pad_k)))
        w_codes = jnp.pad(w_codes, ((0, 0), (0, pad_k), (0, 0)))
    pad_m, pad_n = (-m) % bm, (-n) % bn
    if pad_m:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, pad_m), (0, 0)))
    if pad_n:
        w_codes = jnp.pad(w_codes, ((0, 0), (0, 0), (0, pad_n)))
    out = baseline_expert_matmul_kernel(
        x_codes, w_codes, n_p=n_p, block_m=bm, block_n=bn,
        interpret=interpret,
    )
    return out[:, :m, :n]


def baseline_matmul_int8(
    x_codes: jax.Array,
    w_codes: jax.Array,
    *,
    n_p: int,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """INT32-accumulator W8A8 GEMM baseline; returns INT32 [M, N]."""
    if interpret is None:
        interpret = _default_interpret()
    m, k = x_codes.shape
    n = w_codes.shape[1]
    bm, bn, _ = _resolve_blocks(m, k, n, n_p=n_p, gs=1, block_m=block_m,
                                block_n=block_n, exp_layout="blocked")
    bm = max(bm, min(8, _round_up(m, 8)))  # no m=1 kernel for the baseline
    x_codes, w_codes = ref.pad_ragged_k(x_codes, w_codes, n_p)
    xp = _pad_to(x_codes, bm, 1)
    wp = _pad_to(w_codes, 1, bn)
    out = baseline_matmul_kernel(
        xp, wp, n_p=n_p, block_m=bm, block_n=bn, interpret=interpret,
    )
    return out[:m, :n]


def quantize_operands(
    x: jax.Array, w: jax.Array, *, ax: jax.Array | float, aw: jax.Array | float
):
    """Float activations/weights -> INT8 codes with scales ax (per-tensor)
    and aw (per-tensor or per-column [N])."""
    xq = jnp.clip(jnp.round(x / ax), -128, 127).astype(jnp.int8)
    wq = jnp.clip(jnp.round(w / aw), -128, 127).astype(jnp.int8)
    return xq, wq


def apsq_matmul_f32(
    x: jax.Array,
    w: jax.Array,
    exps: jax.Array,
    *,
    gs: int,
    ax: jax.Array | float,
    aw: jax.Array | float,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Deployment-path float entry: quantize -> integer kernel -> rescale.

    Output scale is product-scale ``ax * aw`` (aw broadcasts per-column).
    """
    xq, wq = quantize_operands(x, w, ax=ax, aw=aw)
    y = apsq_matmul_int8(
        xq, wq, exps, gs=gs, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )
    return y.astype(jnp.float32) * jnp.asarray(ax, jnp.float32) * jnp.asarray(
        aw, jnp.float32
    )


def calibrate_exps(
    x_codes: jax.Array, w_codes: jax.Array, *, n_p: int, gs: int
) -> jax.Array:
    """Exponent calibration from a sample batch (see ref.choose_exps)."""
    return ref.choose_exps(x_codes, w_codes, n_p=n_p, gs=gs)
