"""chatglm3-6b — ChatGLM3 6B [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
2D-RoPE: rotary on half the head dims (rope_fraction=0.5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    norm="rmsnorm",
    mlp="swiglu",
    rope_fraction=0.5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, rope_fraction=0.5,
        dtype="float32")
