"""Fig. 5: normalized energy + accuracy across PSUM precisions (WS, BERT
energy model; accuracy from the QAT testbed at matching PSUM bits)."""
from repro.core import PsumQuantConfig, QuantConfig
from repro.energy import AcceleratorConfig, bert_base, model_energy

from .common import QAT_CFG, train_qat


def run(print_fn=print, steps: int = 50, with_accuracy: bool = True):
    acc = AcceleratorConfig()
    layers = bert_base(128)
    base = model_energy(layers, acc, "WS", psum_bits=32)
    out = []
    for bits in (32, 16, 12, 8, 6, 4):
        e = model_energy(layers, acc, "WS", psum_bits=bits, gs=2)
        rel = e["total"] / base["total"]
        row = {"bits": bits, "energy_rel": rel}
        if with_accuracy and bits <= 16:
            q = QuantConfig(enabled=True,
                            psum=PsumQuantConfig("apsq", gs=2, n_p=8,
                                                 bits=bits))
            _, ev = train_qat(QAT_CFG.with_quant(q), steps=steps)
            row["eval_loss"] = ev
        out.append(row)
        msg = f"fig5,psum_int{bits},energy_rel={rel:.3f}"
        if "eval_loss" in row:
            msg += f",eval_loss={row['eval_loss']:.4f}"
        print_fn(msg)
    print_fn("fig5,headline,energy saving flattens below INT8 while loss "
             "rises (paper: INT8 technically optimal)")
    return out


if __name__ == "__main__":
    run()
