"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> {linear_x -> causal depthwise conv1d(w=4) -> RG-LRU} * gelu(linear_y)
          -> linear_out

RG-LRU (per channel):
    r_t = sigmoid(W_a x_t + b_a)           (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)           (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t) (data-dependent decay, c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth parallel
linear recurrence — the production path for long_500k); decode carries
``h`` one step at a time.  The recurrence is diagonal (not a GEMM), so APSQ
does not apply to the state itself — only to the block's projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import QuantConfig
from .common import Params, dense, init_linear, linear_specs

RGLRU_C = 8.0
CONV_WIDTH = 4


def init_rglru_block(key, d_model: int, d_rnn: int, dtype,
                     quant=None, name: str = "") -> Params:
    ks = jax.random.split(key, 6)
    # Lambda init so decay a in [0.9, 0.999] at r = 1 (Griffin appendix).
    u = jax.random.uniform(ks[0], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))  # inv-softplus
    return {
        "wx": init_linear(ks[1], (d_model, d_rnn), dtype, quant=quant,
                          name=f"{name}.wx"),
        "wy": init_linear(ks[2], (d_model, d_rnn), dtype, quant=quant,
                          name=f"{name}.wy"),
        "conv_w": (jax.random.normal(ks[3], (CONV_WIDTH, d_rnn), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "gate_a": init_linear(ks[4], (d_rnn, d_rnn), dtype),
        "gate_x": init_linear(ks[5], (d_rnn, d_rnn), dtype),
        "gate_a_b": jnp.zeros((d_rnn,), jnp.float32),
        "gate_x_b": jnp.zeros((d_rnn,), jnp.float32),
        "lam": lam,
        "wo": init_linear(jax.random.fold_in(key, 7), (d_rnn, d_model), dtype,
                          quant=quant, name=f"{name}.wo"),
    }


def rglru_block_specs(quant=None, name: str = "") -> Params:
    return {
        "wx": linear_specs(("embed", "rnn"), quant, f"{name}.wx"),
        "wy": linear_specs(("embed", "rnn"), quant, f"{name}.wy"),
        "conv_w": (None, "rnn"),
        "conv_b": ("rnn",),
        "gate_a": linear_specs(("rnn", "rnn_out")),
        "gate_x": linear_specs(("rnn", "rnn_out")),
        "gate_a_b": ("rnn",),
        "gate_x_b": ("rnn",),
        "lam": ("rnn",),
        "wo": linear_specs(("rnn", "embed"), quant, f"{name}.wo"),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                   state: jax.Array | None):
    """Depthwise causal conv, width CONV_WIDTH.  x: [B, S, d].
    state: [B, CONV_WIDTH-1, d] trailing inputs from the previous call."""
    if state is None:
        state = jnp.zeros((x.shape[0], CONV_WIDTH - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
        for i in range(CONV_WIDTH)
    ) + b[None, None].astype(x.dtype)
    new_state = xp[:, -(CONV_WIDTH - 1):]
    return out, new_state


def _rglru_scan(x: jax.Array, a: jax.Array, h0: jax.Array):
    """h_t = a_t h_{t-1} + x_t via associative scan.  All [B, S, d] fp32."""
    # Fold h0 into the first element: h_1 = a_1 h0 + x_1.
    x = x.at[:, 0].add(a[:, 0] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_out, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h


def rglru_block(p: Params, x: jax.Array, *,
                quant=None,
                state: Params | None = None, mesh=None,
                tap: list | None = None, backend=None,
                exact_scan: bool = False):
    """Full recurrent block.  state = {"h": [B, d_rnn] fp32,
    "conv": [B, 3, d_rnn]} or None (fresh).

    ``exact_scan=True`` runs the recurrence as a sequential ``lax.scan``
    instead of the associative scan — same math, but bit-identical to
    S-many single-token calls (the associative tree reorders the fp32
    multiply-adds).  Chunked paged prefill uses this so a chunk matches
    the token-by-token scan exactly."""
    from .common import act_spec, act_spec_seq, shard_hint
    B, S, _ = x.shape
    d_rnn = p["wx"]["w"].shape[-1]
    if S > 1 and mesh is not None and "model" in mesh.axis_names \
            and S % mesh.shape["model"] == 0:
        # Sequence-parallel variant (§Perf it3): gates/gelu/recurrence all
        # run on S/TP tokens with full channels — no TP all-reduce per
        # gate GEMM; the (diagonal) RG-LRU scan still crosses shard
        # boundaries via GSPMD halos.
        rnn_spec = act_spec_seq(mesh, B, S)
    else:
        rnn_spec = act_spec(mesh, B, feat=d_rnn)
    y = jax.nn.gelu(dense(p["wy"], x, quant, tap=tap, backend=backend))
    y = shard_hint(y, rnn_spec)
    xr = dense(p["wx"], x, quant, tap=tap, backend=backend)
    conv_state = state["conv"] if state is not None else None
    xr, new_conv = _causal_conv1d(xr, p["conv_w"], p["conv_b"], conv_state)
    # Keep the whole recurrence sharded on the (diagonal) channel dim —
    # without these hints the rnn x rnn gate GEMMs regather [B,S,d_rnn]
    # per layer (the collective-bound prefill_32k cell in §Perf).
    xr = shard_hint(xr, rnn_spec)

    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(
        shard_hint(dense(p["gate_a"], xr, None), rnn_spec)
        .astype(jnp.float32) + p["gate_a_b"])
    i = jax.nn.sigmoid(
        shard_hint(dense(p["gate_x"], xr, None), rnn_spec)
        .astype(jnp.float32) + p["gate_x_b"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"])[None, None] * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    h0 = (state["h"] if state is not None
          else jnp.zeros((B, xr.shape[-1]), jnp.float32))
    if S == 1:  # decode fast path
        h = (a[:, 0] * h0 + gated[:, 0])[:, None]
    elif exact_scan:
        def step(hc, xs):
            at, gt = xs
            hc = at * hc + gt
            return hc, hc
        _, h = jax.lax.scan(
            step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(gated, 1, 0)))
        h = jnp.moveaxis(h, 0, 1)
    else:
        h = _rglru_scan(gated, a, h0)

    out = dense(p["wo"], (h.astype(x.dtype) * y), quant, tap=tap,
                backend=backend)
    new_state = {"h": h[:, -1], "conv": new_conv}
    return out, new_state


def init_rglru_state(batch: int, d_rnn: int, dtype=jnp.bfloat16):
    return {"h": jnp.zeros((batch, d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, CONV_WIDTH - 1, d_rnn), dtype)}
