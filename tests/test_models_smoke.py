"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes + no NaNs (assignment
requirement), plus decode-state stability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, cells_for, get_config, get_smoke
from repro.core import QuantConfig
from repro.models.model import (
    decode_step,
    encode,
    forward,
    init_decode_state,
    init_lm,
    lm_loss,
    lm_specs,
)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=8):
    tok = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.frontend == "vision":
        kw["embeds"] = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.encdec:
        kw["enc_embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model))
    return tok, kw


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward(name):
    cfg = get_smoke(name)
    p = init_lm(KEY, cfg)
    tok, kw = _batch(cfg)
    logits = forward(p, cfg, tok, **kw)
    exp_s = 8 + (cfg.n_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, exp_s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name):
    cfg = get_smoke(name)
    p = init_lm(KEY, cfg)
    tok, kw = _batch(cfg)

    def loss_fn(p):
        lg = forward(p, cfg, tok, **kw)
        return lm_loss(lg[:, -tok.shape[1]:], tok)

    loss, grads = jax.value_and_grad(loss_fn)(p)
    assert np.isfinite(float(loss)), name
    finite = [bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads)]
    assert all(finite), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_decode_state_stable(name):
    """decode_step returns a state tree with identical structure/shapes/
    dtypes (required for repeated jit-free decode)."""
    cfg = get_smoke(name)
    p = init_lm(KEY, cfg)
    st = init_decode_state(cfg, 2, 16)
    enc_out = None
    if cfg.encdec:
        enc_out = encode(p, cfg, jax.random.normal(KEY, (2, 8, cfg.d_model)))
    tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab)
    lg, st2 = decode_step(p, cfg, st, tok, jnp.asarray(0), enc_out=enc_out)
    assert lg.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))
    assert jax.tree.structure(st) == jax.tree.structure(st2)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("name", ["tinyllama-1.1b", "rwkv6-3b",
                                  "olmoe-1b-7b", "recurrentgemma-2b"])
def test_smoke_apsq_quantized_forward(name):
    """The paper's feature composes with every family."""
    cfg = get_smoke(name).with_quant(QuantConfig.apsq(gs=2, n_p=4))
    p = init_lm(KEY, cfg)
    tok, kw = _batch(cfg)
    logits = forward(p, cfg, tok, **kw)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_registry(name):
    cfg = get_config(name)
    cfg.validate()
    cells = cells_for(name)
    assert "train_4k" in cells and "decode_32k" in cells
    if name in ("rwkv6-3b", "recurrentgemma-2b"):
        assert "long_500k" in cells
    else:
        assert "long_500k" not in cells


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_specs_tree_matches_params(name):
    cfg = get_smoke(name)
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), KEY)
    specs = lm_specs(cfg)
    # every param leaf must have a logical-axes tuple at the same path
    jax.tree.map(lambda sp, sh: None, specs, shapes,
                 is_leaf=lambda x: isinstance(x, tuple))


def test_decode_matches_forward_tinyllama():
    """Greedy continuation via decode == full forward (cache correctness)."""
    cfg = get_smoke("tinyllama-1.1b")
    p = init_lm(KEY, cfg)
    S = 12
    tok = jax.random.randint(KEY, (1, S), 0, cfg.vocab)
    full = forward(p, cfg, tok)
    st = init_decode_state(cfg, 1, 32)
    outs = []
    for t in range(S):
        lg, st = decode_step(p, cfg, st, tok[:, t:t + 1], jnp.asarray(t))
        outs.append(lg)
    stepped = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped),
                               rtol=5e-2, atol=5e-3)
