"""Architecture registry: the 10 assigned architectures (+ paper models).

``get_config(name)`` returns the full published config; ``get_smoke(name)``
the reduced same-family config used by CPU smoke tests.  The paper's own
evaluation models (BERT-Base / Segformer-B0 / EfficientViT-B1 / LLaMA2-7B)
live in ``repro.energy.workloads`` as analytical layer walks.
"""
from __future__ import annotations

import importlib

from repro.core import QuantConfig
from repro.models.config import SHAPE_CELLS, ModelConfig, ShapeCell

_MODULES = {
    "rwkv6-3b": "rwkv6_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "deepseek-7b": "deepseek_7b",
    "chatglm3-6b": "chatglm3_6b",
    "starcoder2-15b": "starcoder2_15b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "internvl2-26b": "internvl2_26b",
}

ARCH_NAMES = tuple(_MODULES)

_MODULE_TO_ARCH = {v: k for k, v in _MODULES.items()}


def canonical_arch(name: str) -> str:
    """Registry id for ``name``, accepting module-style spellings too
    (``tinyllama_1_1b`` == ``tinyllama-1.1b``)."""
    if name in _MODULES:
        return name
    if name in _MODULE_TO_ARCH:
        return _MODULE_TO_ARCH[name]
    raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")


def _module(name: str):
    return importlib.import_module(
        f"repro.configs.{_MODULES[canonical_arch(name)]}")


def get_config(name: str, quant="none", gs: int = 2,
               n_p: int = 8) -> ModelConfig:
    """Full published config, optionally with the paper's PSUM quantization.

    ``quant`` is a preset string ({none, w8a8, psq, apsq}), an explicit
    ``QuantConfig``, or a per-layer ``repro.quant.QuantPolicy`` — string
    presets build the corresponding uniform policy, so every path through
    here yields policy-resolved per-layer quantizer state.
    """
    cfg = _module(name).CONFIG
    if isinstance(quant, str):
        presets = {
            "none": None,
            "apsq": QuantConfig.apsq(gs=gs, n_p=n_p),
            "psq": QuantConfig.psq(n_p=n_p),
            "w8a8": QuantConfig.w8a8(),
        }
        if quant not in presets:
            raise KeyError(f"unknown quant preset {quant!r}; "
                           f"known: {sorted(presets)}")
        quant = presets[quant]
    if quant is not None:
        cfg = cfg.with_quant(quant)
    return cfg.validate()


def get_smoke(name: str, **kw) -> ModelConfig:
    return _module(name).smoke_config().validate()


def cells_for(name: str) -> dict:
    """The assignment's shape cells runnable for this arch.

    ``long_500k`` only for sub-quadratic archs (rwkv6, recurrentgemma);
    full-attention archs skip it (noted in DESIGN.md §5).
    """
    cfg = get_config(name)
    cells = {k: v for k, v in SHAPE_CELLS.items() if k != "long_500k"}
    if cfg.sub_quadratic:
        cells["long_500k"] = SHAPE_CELLS["long_500k"]
    return cells


__all__ = ["ARCH_NAMES", "SHAPE_CELLS", "ModelConfig", "ShapeCell",
           "canonical_arch", "cells_for", "get_config", "get_smoke"]
