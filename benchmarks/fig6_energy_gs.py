"""Fig. 6: normalized energy across gs, models, IS + WS dataflows."""
from repro.energy import (
    AcceleratorConfig,
    bert_base,
    efficientvit_b1,
    model_energy,
    segformer_b0,
)

MODELS = {
    "bert-base-128": bert_base(128),
    "segformer-b0": segformer_b0(),
    "efficientvit-b1": efficientvit_b1(),
}
PAPER = {  # paper-reported savings for reference
    ("bert-base-128", "IS"): "28%", ("bert-base-128", "WS"): "50%",
    ("segformer-b0", "IS"): "42%", ("segformer-b0", "WS"): "87->66%",
    ("efficientvit-b1", "IS"): "40%", ("efficientvit-b1", "WS"): "68->57%",
}


def run(print_fn=print):
    acc = AcceleratorConfig()
    out = {}
    for name, layers in MODELS.items():
        for df in ("IS", "WS"):
            base = model_energy(layers, acc, df, psum_bits=32)
            rels = []
            for gs in (1, 2, 3, 4):
                e = model_energy(layers, acc, df, psum_bits=8, gs=gs)
                rels.append(e["total"] / base["total"])
            out[(name, df)] = rels
            savs = ",".join(f"gs{g}={100 * (1 - r):.0f}%"
                            for g, r in zip((1, 2, 3, 4), rels))
            print_fn(f"fig6,{name},{df},savings:{savs},"
                     f"paper:{PAPER[(name, df)]}")
    return out


if __name__ == "__main__":
    run()
